"""Prefill→decode consistency: decoded logits must match a full forward.

Covers each mixer family: GQA+partial-RoPE (chatglm3), MLA (minicpm3),
MoE (scout), SSD (mamba2), hybrid (jamba), enc-dec (whisper), VLM prefix
(paligemma).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import reduced_config
from repro.models.model import build

FAMILIES = ["chatglm3-6b", "minicpm3-4b", "llama4-scout-17b-a16e",
            "mamba2-1.3b", "jamba-1.5-large-398b", "whisper-base",
            "paligemma-3b"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_prefill_decode_consistency(arch):
    cfg = reduced_config(arch)
    lm = build(cfg)
    params = lm.init(jax.random.key(0))
    B, S, EXTRA = 2, 12, 3
    MAXLEN = S + EXTRA + 4
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (B, S + EXTRA))
    prefix = cfg.vision_tokens

    def mk(n):
        b = {"inputs": jnp.asarray(toks[:, :n], jnp.int32)}
        if cfg.vision_tokens:
            b["patches"] = patches
        if cfg.encoder_layers:
            b["frames"] = frames
        return b

    patches = jnp.asarray(rng.normal(size=(
        B, cfg.vision_tokens, cfg.vision_embed_dim)), jnp.float32) \
        if cfg.vision_tokens else None
    frames = jnp.asarray(rng.normal(size=(
        B, cfg.encoder_seq, cfg.d_model)), jnp.float32) \
        if cfg.encoder_layers else None

    prefill = jax.jit(lambda p, b: lm.prefill(p, b, MAXLEN + prefix))
    step = jax.jit(lm.decode_step)
    logits, cache = prefill(params, mk(S))
    decoded = [logits]
    for i in range(EXTRA):
        tok = jnp.asarray(toks[:, S + i:S + i + 1], jnp.int32)
        logits, cache = step(params, cache, tok, jnp.int32(prefix + S + i))
        decoded.append(logits)
    for i, d in enumerate(decoded):
        ref, _ = prefill(params, mk(S + i))
        err = float(jnp.max(jnp.abs(d - ref)))
        assert err < 2e-2, (arch, i, err)


def test_decode_does_not_peek_future():
    """Causality: token t's decode logits are independent of tokens > t."""
    cfg = reduced_config("qwen1.5-0.5b")
    lm = build(cfg)
    params = lm.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    t1 = rng.integers(0, cfg.vocab_size, (1, 10))
    t2 = t1.copy()
    t2[:, -3:] = rng.integers(0, cfg.vocab_size, (1, 3))  # mutate tail
    prefill = jax.jit(lambda p, b: lm.prefill(p, b, 16))
    l1, _ = prefill(params, {"inputs": jnp.asarray(t1[:, :7], jnp.int32)})
    l2, _ = prefill(params, {"inputs": jnp.asarray(t2[:, :7], jnp.int32)})
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-6, atol=1e-6)


def test_int8_kv_cache_decode():
    """§Perf B3: int8 KV cache matches full-precision decode closely and
    halves (+) the cache footprint."""
    import dataclasses
    cfg = reduced_config("chatglm3-6b")
    cfg_q = dataclasses.replace(cfg, kv_cache_quant=True)
    lm, lm_q = build(cfg), build(cfg_q)
    params = lm.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (2, 20))
    b = {"inputs": jnp.asarray(toks[:, :16], jnp.int32)}
    l0, c0 = jax.jit(lambda p, x: lm.prefill(p, x, 28))(params, b)
    l1, c1 = jax.jit(lambda p, x: lm_q.prefill(p, x, 28))(params, b)
    assert c1["sub0"]["k"].dtype == jnp.int8
    s0, s1 = jax.jit(lm.decode_step), jax.jit(lm_q.decode_step)
    errs = [float(jnp.max(jnp.abs(l0 - l1)))]
    for i in range(3):
        t = jnp.asarray(toks[:, 16 + i:17 + i], jnp.int32)
        l0, c0 = s0(params, c0, t, jnp.int32(16 + i))
        l1, c1 = s1(params, c1, t, jnp.int32(16 + i))
        errs.append(float(jnp.max(jnp.abs(l0 - l1))))
    assert max(errs) < 0.15, errs
    full = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c0))
    quant = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(c1))
    assert quant < 0.6 * full
