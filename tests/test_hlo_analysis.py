"""Scan-aware HLO analyzer: trip-count propagation on a toy module."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import hlo_analysis as H

TOY_HLO = """\
HloModule toy

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16] get-tuple-element(%p), index=1
  %w = f32[16,16] constant({...})
  %dot.1 = f32[8,16] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16] all-reduce(%dot.1), replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %niv = s32[] add(%iv, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%niv, %ar)
}

%cond (p2: (s32[], f32[8,16])) -> pred[] {
  %p2 = (s32[], f32[8,16]) parameter(0)
  %iv2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%iv2, %n), direction=LT
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x0: f32[8,16]) -> f32[8,16] {
  %x0 = f32[8,16] parameter(0)
  %c0 = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%c0, %x0)
  %loop = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,16] get-tuple-element(%loop), index=1
}
"""


def test_trip_count_multiplies_flops_and_collectives():
    res = H.analyze(TOY_HLO)
    # dot: 2*8*16*16 = 4096 flops × 5 trips
    assert res["flops"] == 5 * 2 * 8 * 16 * 16
    # all-reduce: 2 × 8*16*4 bytes × 5 trips
    assert res["collectives"]["bytes"]["all-reduce"] == 5 * 2 * 8 * 16 * 4
    assert res["collectives"]["counts"]["all-reduce"] == 5


def test_cond_constant_fallback():
    txt = TOY_HLO.replace(
        ', backend_config={"known_trip_count":{"n":"5"}}', "")
    res = H.analyze(txt)
    assert res["flops"] == 5 * 2 * 8 * 16 * 16  # from %cond constant(5)


def test_on_real_jax_lowering():
    """End-to-end: a scanned matmul's flops ≈ trips × per-step flops."""
    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((8, 64), jnp.float32))
    res = H.analyze(lowered.compile().as_text())
    expect = 7 * 2 * 8 * 64 * 64
    assert abs(res["flops"] - expect) / expect < 0.01
    assert res["hbm_bytes"] > 0
