"""Serving-tier observability: metrics-backed stats, per-owner drop
accounting, snapshot consistency under concurrency, and the end-to-end
trace of the headline example."""
import importlib
import json
import logging
import threading

import numpy as np
import pytest

from repro import obs
from repro.core import format as F
from repro.core.registry import MatrixRegistry
from repro.data import matrices as M
from repro.serve.spmv_service import SpMVService

CFG = F.SerpensConfig(segment_width=512, lanes=16, sublanes=8)


@pytest.fixture(autouse=True)
def _clean_tracer():
    obs.disable()
    obs.clear()
    yield
    obs.disable()
    obs.clear()


def make_service(n=256, nnz=2_000, seed=0, **kw):
    rows, cols, vals = M.uniform_random(n, n, nnz, seed=seed)
    reg = MatrixRegistry(config=CFG, backend="xla")
    mid = reg.put(rows, cols, vals, (n, n))
    return SpMVService(reg, backend="xla", **kw), reg, mid, n


class TestSnapshotLatency:
    def test_snapshot_reports_exact_percentiles(self):
        svc, reg, mid, n = make_service()
        # Bypass dispatch timing noise: feed the histogram directly and
        # check the snapshot surfaces the exact nearest-rank values.
        for v in range(1, 101):
            svc._m_dispatch_lat.observe(v / 1000.0)
        snap = svc.snapshot()
        assert snap["dispatch_latency_p50"] == pytest.approx(0.050)
        assert snap["dispatch_latency_p95"] == pytest.approx(0.095)
        assert snap["dispatch_latency_p99"] == pytest.approx(0.099)

    def test_dispatch_populates_latency_histogram(self):
        svc, reg, mid, n = make_service()
        x = np.ones(n, np.float32)
        for _ in range(4):
            svc.submit(mid, x)
        svc.flush()
        assert svc._m_dispatch_lat.count == 4
        snap = svc.snapshot()
        assert snap["dispatch_latency_p50"] > 0
        assert snap["dispatch_latency_p99"] >= snap["dispatch_latency_p50"]

    def test_stats_dataclass_still_backward_compatible(self):
        svc, reg, mid, n = make_service()
        x = np.ones(n, np.float32)
        svc.submit(mid, x)
        svc.submit(mid, x)
        svc.flush()
        assert svc.stats.batches == 1
        assert svc.stats.vectors == 2
        assert svc.stats.stream_bytes > 0
        assert svc.stats.mean_batch_size == 2.0
        ss = svc.stats_snapshot()
        assert ss.vectors == 2

    def test_metrics_are_private_per_service(self):
        svc1, reg, mid, n = make_service()
        svc2 = SpMVService(reg, backend="xla")
        svc1.submit(mid, np.ones(n, np.float32))
        svc1.flush()
        assert svc1.stats.vectors == 1
        assert svc2.stats.vectors == 0      # no aliasing across services


class TestOwnerAccounting:
    def test_dropped_results_charged_to_owner_and_logged(self, caplog):
        # Per-owner result queues: caller-0 deposits 3 results into a
        # queue capped at 2 (1 drop, charged to caller-0 alone); caller-1
        # deposits 2 and loses nothing — one noisy caller can no longer
        # evict another caller's results.
        svc, reg, mid, n = make_service(max_stored_results=2)
        x = np.ones(n, np.float32)
        for i in range(5):
            svc.submit(mid, x, owner=f"caller-{i % 2}")
        with caplog.at_level(logging.WARNING, logger="repro.serve"):
            svc.flush()
        assert svc.stats.results_dropped == 1
        by_owner = svc.results_dropped_by_owner()
        assert by_owner == {"caller-0": 1}
        dropped_logs = [r for r in caplog.records
                        if "spmv_result_dropped" in r.message]
        assert len(dropped_logs) == 1
        assert "owner=caller-0" in dropped_logs[0].getMessage()

    def test_owner_defaults_to_thread_name(self):
        svc, reg, mid, n = make_service()
        t = svc.submit(mid, np.ones(n, np.float32))
        svc.flush()
        res = svc.result(t)
        assert res.owner == threading.current_thread().name

    def test_snapshot_includes_per_owner_drops(self):
        # Queues are per owner: "victim" overflows its own cap-1 queue
        # (oldest result dropped), while "keeper"'s queue is untouched.
        svc, reg, mid, n = make_service(max_stored_results=1)
        x = np.ones(n, np.float32)
        svc.submit(mid, x, owner="victim")
        svc.submit(mid, x, owner="victim")
        keeper_t = svc.submit(mid, x, owner="keeper")
        svc.flush()
        snap = svc.snapshot()
        assert snap["results_dropped"] == 1
        assert snap["results_dropped_by_owner"] == {"victim": 1}
        assert svc.result(keeper_t, timeout=1.0).owner == "keeper"


class TestConcurrentSnapshots:
    def test_no_torn_or_negative_values_across_100_snapshots(self):
        """stats/snapshot() reads must stay internally consistent while
        submit/flush/update churn on other threads."""
        svc, reg, mid, n = make_service(nnz=1_500)
        stop = threading.Event()
        errors = []

        def churn_requests():
            x = np.ones(n, np.float32)
            while not stop.is_set():
                for _ in range(3):
                    svc.submit(mid, x)
                try:
                    svc.flush()
                except Exception as e:          # pragma: no cover
                    errors.append(e)
                    return

        def churn_updates():
            rng = np.random.default_rng(9)
            while not stop.is_set():
                r = rng.integers(0, n, 8)
                c = rng.integers(0, n, 8)
                try:
                    svc.update(mid, r, c, np.ones(8, np.float32))
                except Exception as e:          # pragma: no cover
                    errors.append(e)
                    return

        threads = [threading.Thread(target=churn_requests),
                   threading.Thread(target=churn_requests),
                   threading.Thread(target=churn_updates)]
        for t in threads:
            t.start()
        try:
            for _ in range(100):
                ss = svc.stats_snapshot()
                snap = svc.snapshot()
                # Non-negativity: a rollback must never be observable as
                # a negative counter.
                assert ss.batches >= 0 and ss.vectors >= 0
                assert ss.stream_bytes >= 0 and ss.deferred >= 0
                assert ss.results_dropped >= 0
                # Internal consistency: vectors never exceed what the
                # dispatched batches could have carried, and the derived
                # ratios are finite.
                assert ss.vectors <= ss.batches * svc.max_bucket
                assert ss.amortized_bytes_per_vector >= 0
                assert snap["vectors"] == snap["vectors"]  # not NaN
                assert snap["dispatch_latency_p99"] >= 0
        finally:
            stop.set()
            for t in threads:
                t.join()
        assert not errors


class TestRequestTrace:
    def test_trace_covers_every_request_lifecycle(self):
        """Every ticket in a mixed workload appears as flow start (submit)
        + step (dispatch) + end (collect), with the lifecycle spans."""
        svc, reg, mid, n = make_service()
        obs.clear()
        obs.enable()
        x = np.ones(n, np.float32)
        tickets = [svc.submit(mid, x) for _ in range(6)]
        svc.flush()
        for t in tickets:
            svc.result(t)
        obs.disable()
        doc = obs.export_chrome_trace()
        evs = doc["traceEvents"]
        names = {e["name"] for e in evs if e["ph"] == "X"}
        for expected in ("submit", "flush", "coalesce", "dispatch",
                         "compute", "device-block", "result-collect"):
            assert expected in names, f"missing span {expected!r}"
        flows = {}
        for e in evs:
            if e["ph"] in ("s", "t", "f"):
                flows.setdefault(e["id"], set()).add(e["ph"])
        for t in tickets:
            assert flows.get(t) == {"s", "t", "f"}, (
                f"ticket {t} lifecycle incomplete: {flows.get(t)}")

    def test_serve_fallback_closes_the_flow(self):
        svc, reg, mid, n = make_service()
        obs.clear()
        obs.enable()
        svc.serve([(mid, np.ones(n, np.float32))])
        obs.disable()
        evs = obs.export_chrome_trace()["traceEvents"]
        assert any(e["ph"] == "f" for e in evs)


class TestTraceServingExample:
    def test_example_emits_schema_valid_covering_trace(self, tmp_path):
        mod = importlib.import_module("examples.trace_serving")
        out = tmp_path / "trace.json"
        res = mod.main(["--out", str(out), "--requests", "3"])
        doc = json.loads(out.read_text())
        obs.validate_chrome_trace(doc)
        assert res["snapshot"]["vectors"] == len(res["tickets"]) == 9
        # Acceptance: spans cover submit -> dispatch -> result for every
        # request in the mixed workload.
        flows = {}
        for e in doc["traceEvents"]:
            if e.get("ph") in ("s", "t", "f"):
                flows.setdefault(e["id"], set()).add(e["ph"])
        for t in res["tickets"]:
            assert {"s", "t", "f"} <= flows.get(t, set()), (
                f"ticket {t}: incomplete flow {flows.get(t)}")
        names = {e["name"] for e in doc["traceEvents"]}
        assert {"submit", "dispatch", "result-collect"} <= names
