"""Observability substrate: tracer, metrics, Chrome-trace export."""
import json
import threading

import pytest

from repro import obs
from repro.obs.export import (export_chrome_trace, validate_chrome_trace,
                              write_chrome_trace)
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry)
from repro.obs.trace import Tracer


@pytest.fixture(autouse=True)
def _clean_tracer():
    """Every test starts and ends with the global tracer off and empty."""
    obs.disable()
    obs.clear()
    yield
    obs.disable()
    obs.clear()


# -- tracing ----------------------------------------------------------------

class TestTracer:
    def test_disabled_by_default_records_nothing(self):
        with obs.span("x", a=1) as sp:
            sp.args["b"] = 2
        obs.instant("y")
        obs.flow_start("f", 1)
        assert obs.TRACER.event_count() == 0

    def test_disabled_span_is_shared_noop(self):
        s1 = obs.span("a")
        s2 = obs.span("b", k=1)
        assert s1 is s2                 # no allocation on the cold path

    def test_span_records_duration_and_args(self):
        obs.enable()
        with obs.span("work", matrix="m1") as sp:
            sp.args["late"] = 7
        bufs = obs.TRACER.buffers()
        assert len(bufs) == 1
        ph, name, cat, ts, dur, args, fid = bufs[0].events[0]
        assert ph == "X" and name == "work" and dur >= 0
        assert args == {"matrix": "m1", "late": 7}

    def test_span_emits_even_when_body_raises(self):
        obs.enable()
        with pytest.raises(RuntimeError):
            with obs.span("boom"):
                raise RuntimeError("x")
        assert obs.TRACER.event_count() == 1

    def test_event_records_explicit_duration(self):
        obs.enable()
        obs.event("shipped", 0.5, range=3)
        (ph, name, _, _, dur, args, _), = obs.TRACER.buffers()[0].events
        assert ph == "X" and dur == int(0.5e9) and args == {"range": 3}

    def test_flow_events(self):
        obs.enable()
        obs.flow_start("req", 42)
        obs.flow_step("req", 42)
        obs.flow_end("req", 42)
        phases = [e[0] for e in obs.TRACER.buffers()[0].events]
        fids = {e[6] for e in obs.TRACER.buffers()[0].events}
        assert phases == ["s", "t", "f"] and fids == {42}

    def test_ring_overflow_drops_oldest_and_counts(self):
        tr = Tracer(max_events_per_thread=8)
        tr.enable()
        for i in range(20):
            tr.instant(f"e{i}")
        buf = tr.buffers()[0]
        assert len(buf.events) == 8
        assert buf.dropped == 12
        assert buf.events[0][1] == "e12"    # oldest kept

    def test_per_thread_buffers(self):
        obs.enable()

        def work():
            obs.instant("from-thread")

        t = threading.Thread(target=work, name="worker-1")
        t.start()
        t.join()
        obs.instant("from-main")
        names = {b.thread_name for b in obs.TRACER.buffers()}
        assert "worker-1" in names and len(obs.TRACER.buffers()) == 2

    def test_clear_resets_buffers_and_epoch(self):
        obs.enable()
        obs.instant("x")
        assert obs.TRACER.event_count() == 1
        obs.clear()
        assert obs.TRACER.event_count() == 0
        obs.instant("y")                # stale tls buffer must re-register
        assert obs.TRACER.event_count() == 1

    def test_context_inheritance_across_threads(self):
        obs.enable()
        with obs.attach_context({}, request="r9"):
            ctx = obs.capture_context()

        def work():
            with obs.attach_context(ctx, worker=1):
                obs.instant("inside")
            obs.instant("outside")

        t = threading.Thread(target=work)
        t.start()
        t.join()
        buf = next(b for b in obs.TRACER.buffers()
                   if any(e[1] == "inside" for e in b.events))
        by_name = {e[1]: e[5] for e in buf.events}
        assert by_name["inside"] == {"request": "r9", "worker": 1}
        assert by_name["outside"] is None

    def test_attach_context_nests_and_restores(self):
        obs.enable()
        with obs.attach_context({"a": 1}):
            with obs.attach_context({"b": 2}):
                assert obs.capture_context() == {"a": 1, "b": 2}
            assert obs.capture_context() == {"a": 1}
        assert obs.capture_context() == {}


# -- export -----------------------------------------------------------------

class TestExport:
    def test_export_schema_and_metadata(self, tmp_path):
        obs.enable()
        with obs.span("s", k="v"):
            pass
        obs.instant("i")
        obs.flow_start("req", 7)
        obs.flow_end("req", 7)
        path = tmp_path / "t.json"
        doc = write_chrome_trace(str(path))
        validate_chrome_trace(doc)
        on_disk = json.loads(path.read_text())
        assert on_disk == doc
        evs = doc["traceEvents"]
        assert any(e["ph"] == "M" and e["name"] == "process_name"
                   for e in evs)
        x = next(e for e in evs if e["ph"] == "X")
        assert x["name"] == "s" and x["args"] == {"k": "v"} \
            and x["dur"] >= 0
        f = next(e for e in evs if e["ph"] == "f")
        assert f["id"] == 7 and f["bp"] == "e"

    def test_export_reports_drops_in_thread_metadata(self):
        tr = Tracer(max_events_per_thread=4)
        tr.enable()
        for i in range(10):
            tr.instant(f"e{i}")
        doc = export_chrome_trace(tr)
        meta = next(e for e in doc["traceEvents"]
                    if e["name"] == "thread_name")
        assert meta["args"]["dropped_events"] == 6

    @pytest.mark.parametrize("bad", [
        [],                                            # not a dict
        {"traceEvents": {}},                           # not a list
        {"traceEvents": [{"ph": "Z", "name": "x",
                          "pid": 1, "tid": 1, "ts": 0}]},   # bad phase
        {"traceEvents": [{"ph": "X", "name": "",
                          "pid": 1, "tid": 1, "ts": 0,
                          "dur": 1}]},                 # empty name
        {"traceEvents": [{"ph": "X", "name": "x",
                          "pid": 1, "tid": 1, "ts": 0}]},   # X w/o dur
        {"traceEvents": [{"ph": "s", "name": "x",
                          "pid": 1, "tid": 1, "ts": 0}]},   # flow w/o id
    ])
    def test_validate_rejects_malformed(self, bad):
        with pytest.raises(ValueError):
            validate_chrome_trace(bad)


# -- metrics ----------------------------------------------------------------

class TestCounterGauge:
    def test_counter_inc_add_and_labels(self):
        c = Counter("reqs")
        c.inc()
        c.add(2.5)
        c.inc(owner="a")
        c.inc(owner="a")
        c.inc(owner="b")
        assert c.value() == 3.5
        assert c.value(owner="a") == 2
        assert c.total() == 6.5

    def test_counter_negative_add_rolls_back(self):
        c = Counter("work")
        c.add(5)
        c.add(-3)           # the service's flush-failure rollback path
        assert c.value() == 2

    def test_gauge_set_and_add(self):
        g = Gauge("depth")
        g.set(4)
        g.add(-1)
        assert g.value() == 3

    def test_invalid_metric_name(self):
        with pytest.raises(ValueError):
            Counter("bad name!")


class TestHistogram:
    def test_boundary_value_lands_in_le_bucket(self):
        h = Histogram("h", buckets=(0.001, 0.01, 0.1))
        for v in (0.001, 0.0005, 0.01, 0.05, 0.5):
            h.observe(v)
        # le-inclusive: 0.001 and 0.0005 in the first bucket, 0.01 in the
        # second, 0.05 in the third, 0.5 overflows.
        assert h.bucket_counts() == [2, 1, 1, 1]

    def test_exact_percentiles_nearest_rank(self):
        h = Histogram("h", buckets=(1.0,))
        for v in range(1, 101):      # 1..100
            h.observe(float(v))
        assert h.percentile(50) == 50.0
        assert h.percentile(95) == 95.0
        assert h.percentile(99) == 99.0
        assert h.percentile(100) == 100.0
        assert h.percentile(1) == 1.0

    def test_percentile_empty_and_bad_p(self):
        h = Histogram("h")
        assert h.percentile(50) == 0.0
        with pytest.raises(ValueError):
            h.percentile(0)
        with pytest.raises(ValueError):
            h.percentile(101)

    def test_bucket_percentile_interpolates_and_clamps(self):
        h = Histogram("h", buckets=(1.0, 2.0), max_samples=0)
        for _ in range(10):
            h.observe(1.5)           # all in the (1.0, 2.0] bucket
        assert h.percentile(50) == pytest.approx(1.5)   # falls back
        h.observe(5.0)               # overflow clamps to last bound
        assert h.bucket_percentile(100) == 2.0

    def test_sample_window_bounds_memory(self):
        h = Histogram("h", buckets=(1.0,), max_samples=4)
        for v in (1, 2, 3, 4, 5, 6):
            h.observe(float(v))
        assert h.count == 6
        assert h.percentile(100) == 6.0     # window keeps 3,4,5,6
        assert h.percentile(1) == 3.0

    def test_rejects_bad_buckets(self):
        with pytest.raises(ValueError):
            Histogram("h", buckets=())
        with pytest.raises(ValueError):
            Histogram("h", buckets=(2.0, 1.0))


class TestRegistry:
    def test_get_or_create_returns_same_instance(self):
        r = MetricsRegistry()
        a = r.counter("x")
        b = r.counter("x")
        assert a is b

    def test_type_conflict_raises(self):
        r = MetricsRegistry()
        r.counter("x")
        with pytest.raises(ValueError):
            r.histogram("x")

    def test_snapshot_shape(self):
        r = MetricsRegistry()
        r.counter("c").inc(3)
        r.histogram("h").observe(0.01)
        snap = r.snapshot()
        assert snap["c"]["total"] == 3
        assert snap["h"]["count"] == 1 and snap["h"]["p50"] == 0.01

    def test_prometheus_text_exposition(self):
        r = MetricsRegistry()
        r.counter("reqs", "requests").inc(2, owner="a")
        h = r.histogram("lat", buckets=(0.1, 1.0))
        h.observe(0.05)
        h.observe(0.5)
        text = r.prometheus_text()
        assert "# TYPE reqs counter" in text
        assert 'reqs{owner="a"} 2' in text
        assert 'lat_bucket{le="0.1"} 1' in text
        assert 'lat_bucket{le="1"} 2' in text
        assert 'lat_bucket{le="+Inf"} 2' in text
        assert "lat_count 2" in text

    def test_label_escaping(self):
        r = MetricsRegistry()
        r.counter("c").inc(owner='we"ird\\name')
        assert '\\"' in r.prometheus_text()


def test_obs_package_does_not_import_jax():
    """obs must stay importable from numpy-only encode workers."""
    import subprocess
    import sys
    code = ("import sys; import repro.obs; "
            "sys.exit(1 if 'jax' in sys.modules else 0)")
    proc = subprocess.run([sys.executable, "-c", code])
    assert proc.returncode == 0
