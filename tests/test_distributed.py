"""Multi-device semantics, run in a subprocess with 8 host CPU devices
(the main pytest process stays single-device)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro import compat
from repro.core.distributed import ShardedSerpensSpMV
from repro.core import format as F
from repro.core.spmv import SerpensSpMV
from repro.data import matrices as M
from repro.kernels.ref import spmv_coo_ref
from repro.launch.mesh import make_host_mesh
from repro.models import layers as L
from repro.models.model import build
from repro.configs import reduced_config
from repro.train.compression import compressed_psum, quantize_int8
from repro.launch import sharding as sh
from repro.serve.engine import ServeEngine

ok = []

# --- 1. distributed SpMV (row & col partitions) == oracle ----------------
rows, cols, vals = M.uniform_random(600, 800, 5000, seed=1)
x = np.random.default_rng(0).normal(size=800).astype(np.float32)
y0 = np.random.default_rng(1).normal(size=600).astype(np.float32)
cfg = F.SerpensConfig(segment_width=128, lanes=16, sublanes=8)
ref = spmv_coo_ref(jnp.asarray(rows), jnp.asarray(cols), jnp.asarray(vals),
                   jnp.asarray(x), 600, 1.5, 0.5, jnp.asarray(y0))
mesh8 = compat.make_mesh((8,), ("x",))
for part in ("row", "col"):
    d = ShardedSerpensSpMV(rows, cols, vals, (600, 800), mesh8, "x",
                           part, cfg)
    got = d(x, alpha=1.5, beta=0.5, y=y0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    ok.append(f"spmv-{part}")

# --- 1b. sharded path preserves the aux spill stream (OPTIMIZED-style
# config) and reaches the Pallas kernel + matmat — all through the one
# channel-shard execution core -------------------------------------------
cfg_opt = F.SerpensConfig(segment_width=128, lanes=16, sublanes=8,
                          raw_window=2, spill_hot_rows=True,
                          lane_balance=1.1)
rows_h = rows.copy(); rows_h[:len(rows_h) // 3] = 0   # hot row 0 -> spills
ref_h = spmv_coo_ref(jnp.asarray(rows_h), jnp.asarray(cols),
                     jnp.asarray(vals), jnp.asarray(x), 600)
xm = np.random.default_rng(2).normal(size=(800, 4)).astype(np.float32)
dense_h = np.zeros((600, 800), np.float32)
np.add.at(dense_h, (rows_h, cols), vals)
for part in ("row", "col"):
    d = ShardedSerpensSpMV(rows_h, cols, vals, (600, 800), mesh8, "x",
                           part, cfg_opt)
    assert d.plan.n_aux > 0, "spill stream must engage"
    np.testing.assert_allclose(np.asarray(d.matvec(x)), np.asarray(ref_h),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(d.matmat(xm)), dense_h @ xm,
                               rtol=2e-4, atol=2e-4)
    ok.append(f"spmv-{part}-spill")
d = ShardedSerpensSpMV(rows_h, cols, vals, (600, 800), mesh8, "x",
                       "row", cfg_opt, backend="pallas")
np.testing.assert_allclose(np.asarray(d.matvec(x)), np.asarray(ref_h),
                           rtol=2e-4, atol=2e-4)
ok.append("spmv-row-pallas")

# --- 1c. registry: single-shard put repartitions once onto the 8-mesh ----
from repro.core.registry import MatrixRegistry
reg = MatrixRegistry(config=cfg, backend="xla")
mid = reg.put(rows, cols, vals, (600, 800))        # single-shard plan
op8 = reg.get(mid, mesh=mesh8, axis="x")           # row/8: repartition
assert op8.plan.num_shards == 8 and reg.stats.encodes == 2
assert reg.get(mid, mesh=mesh8, axis="x") is op8   # cached thereafter
assert reg.stats.encodes == 2
ref_p = spmv_coo_ref(jnp.asarray(rows), jnp.asarray(cols),
                     jnp.asarray(vals), jnp.asarray(x), 600)
np.testing.assert_allclose(np.asarray(op8.matvec(x)), np.asarray(ref_p),
                           rtol=2e-4, atol=2e-4)
ok.append("registry-remesh")

# --- 2. compressed psum ≈ exact psum --------------------------------------
def body(g):
    return compressed_psum(g, "x")
g = np.random.default_rng(2).normal(size=(8, 128)).astype(np.float32)
f = compat.shard_map(body, mesh=mesh8, in_specs=P("x"), out_specs=P("x"))
approx = np.asarray(f(jnp.asarray(g)))[0]
exact = g.sum(0)
rel = np.abs(approx - exact).max() / (np.abs(exact).max() + 1e-9)
assert rel < 0.02, rel
ok.append("compressed-psum")

# --- 3. model on a (4, 2) mesh == single device ---------------------------
mesh = make_host_mesh(4, 2)
cfg_m = reduced_config("chatglm3-6b")
lm = build(cfg_m)
params = lm.init(jax.random.key(0))
toks = np.random.default_rng(3).integers(0, cfg_m.vocab_size, (8, 17))
batch = {"inputs": jnp.asarray(toks[:, :-1], jnp.int32),
         "labels": jnp.asarray(toks[:, 1:], jnp.int32)}
loss_single, _ = jax.jit(lm.loss)(params, batch)
pspecs = sh.param_specs(params)
pshard = sh.to_shardings(mesh, pspecs)
params_sharded = jax.tree.map(jax.device_put, params, pshard)
with L.mesh_context(mesh), mesh:
    loss_mesh, _ = jax.jit(lm.loss)(params_sharded, batch)
assert abs(float(loss_single) - float(loss_mesh)) < 1e-2, \
    (float(loss_single), float(loss_mesh))
ok.append("mesh-loss-equiv")

# --- 4. MoE EP serve path (shard_map) == no-mesh ragged path --------------
cfg_moe = reduced_config("llama4-scout-17b-a16e")
lm2 = build(cfg_moe)
p2 = lm2.init(jax.random.key(1))
b2 = {"inputs": jnp.asarray(
    np.random.default_rng(4).integers(0, cfg_moe.vocab_size, (8, 8)),
    jnp.int32)}
lg_plain, _ = jax.jit(lambda p, b: lm2.prefill(p, b, 12))(p2, b2)
p2s = jax.tree.map(jax.device_put, p2,
                   sh.to_shardings(mesh, sh.param_specs(p2)))
with L.mesh_context(mesh), mesh:
    lg_mesh, _ = jax.jit(lambda p, b: lm2.prefill(p, b, 12))(p2s, b2)
err = float(jnp.max(jnp.abs(lg_plain - lg_mesh)))
assert err < 2e-2, err
ok.append("moe-ep-serve")

# --- 5. seq-sharded decode == plain decode --------------------------------
eng = ServeEngine(lm, params, max_len=32)
l0, c0 = eng.prefill({"inputs": batch["inputs"][:1]})
l0b, _ = eng.decode_step(c0, batch["inputs"][:1, :1], jnp.int32(16))
mesh41 = make_host_mesh(4, 1)
eng2 = ServeEngine(lm, params, max_len=32, mesh=mesh41, shard_kv_seq=True)
l1, c1 = eng2.prefill({"inputs": batch["inputs"][:1]})
cspec = sh.cache_specs(cfg_m, c1, mesh41, shard_seq=True)
c1 = jax.tree.map(jax.device_put, c1, sh.to_shardings(mesh41, cspec))
l1b, _ = eng2.decode_step(c1, batch["inputs"][:1, :1], jnp.int32(16))
assert float(jnp.max(jnp.abs(l1b - l0b))) < 1e-3
ok.append("seq-sharded-decode")

# --- 5b. elastic restart: checkpoint from 1-device run restores onto a
# (4,2) mesh and training continues (mesh-agnostic checkpoints) ------------
import tempfile
from repro.train.trainer import Trainer, TrainConfig
from repro.train.optimizer import OptimizerConfig
from repro.data.pipeline import SyntheticLM

data = SyntheticLM(cfg_m.vocab_size, 24, 8, seed=5)
with tempfile.TemporaryDirectory() as d:
    opt = OptimizerConfig(lr=5e-3, warmup_steps=2, total_steps=16)
    t1 = Trainer(build(reduced_config("chatglm3-6b")),
                 lambda s: data.batch_at(s),
                 TrainConfig(steps=8, ckpt_dir=d, ckpt_every=8,
                             ckpt_async=False, opt=opt))          # no mesh
    t1.run()
    t2 = Trainer(build(reduced_config("chatglm3-6b")),
                 lambda s: data.batch_at(s),
                 TrainConfig(steps=16, ckpt_dir=d, ckpt_every=8,
                             ckpt_async=False, opt=opt),
                 mesh=make_host_mesh(4, 2))                        # re-mesh
    assert t2.step == 8
    hist = t2.run()
    assert hist and hist[-1]["step"] == 16
    assert np.isfinite(hist[-1]["loss"])
ok.append("elastic-remesh")

# --- 5c. distributed SpMV strong scaling (row partition, 1→8 devices) -----
import time as _time
rows8, cols8, vals8 = M.uniform_random(4096, 4096, 120_000, seed=9)
x8 = np.random.default_rng(9).normal(size=4096).astype(np.float32)
ref8 = spmv_coo_ref(jnp.asarray(rows8), jnp.asarray(cols8),
                    jnp.asarray(vals8), jnp.asarray(x8), 4096)
for nd in (1, 8):
    mesh_n = compat.make_mesh((nd,), ("x",))
    dd = ShardedSerpensSpMV(rows8, cols8, vals8, (4096, 4096), mesh_n,
                            "x", "row", cfg)
    got8 = dd(x8)
    np.testing.assert_allclose(np.asarray(got8), np.asarray(ref8),
                               rtol=2e-4, atol=2e-4)
ok.append("spmv-scaling")

# --- 6. B2 weight-stationary decode == plain decode -----------------------
# dense FFN path (chatglm) and MoE-EP decode path (scout), batch sharded
for cfg_x, lm_x, p_x, name in ((cfg_m, lm, params, "dense"),
                               (cfg_moe, lm2, p2, "moe")):
    toks6 = np.random.default_rng(6).integers(0, cfg_x.vocab_size, (8, 9))
    b6 = {"inputs": jnp.asarray(toks6[:, :8], jnp.int32)}
    lg0, c0 = jax.jit(lambda p, b: lm_x.prefill(p, b, 12))(p_x, b6)
    lg0b, _ = jax.jit(lm_x.decode_step)(p_x, c0,
                                        jnp.asarray(toks6[:, 8:9]),
                                        jnp.int32(8))
    p_sh = jax.tree.map(jax.device_put, p_x,
                        sh.to_shardings(mesh, sh.param_specs(p_x)))
    with L.mesh_context(mesh), mesh:
        lg1, c1 = jax.jit(lambda p, b: lm_x.prefill(p, b, 12))(p_sh, b6)
        cspec = sh.cache_specs(cfg_x, c1, mesh)
        c1 = jax.tree.map(jax.device_put, c1,
                          sh.to_shardings(mesh, cspec))
        lg1b, _ = jax.jit(lm_x.decode_step)(p_sh, c1,
                                            jnp.asarray(toks6[:, 8:9]),
                                            jnp.int32(8))
    err = float(jnp.max(jnp.abs(lg1b - lg0b)))
    assert err < 2e-2, (name, err)
    ok.append(f"b2-decode-{name}")

print("PASS:" + ",".join(ok))
"""


def test_distributed_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    # CPU platform, 8 simulated devices via XLA_FLAGS (see test_launchers
    # for why leaving the platform unset stalls on libtpu metadata probes).
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert res.returncode == 0, res.stdout[-3000:] + res.stderr[-3000:]
    assert "PASS:" in res.stdout
    passed = res.stdout.strip().split("PASS:")[-1].split(",")
    assert set(passed) == {"spmv-row", "spmv-col", "spmv-row-spill",
                           "spmv-col-spill", "spmv-row-pallas",
                           "registry-remesh",
                           "compressed-psum", "mesh-loss-equiv",
                           "moe-ep-serve", "seq-sharded-decode",
                           "elastic-remesh", "spmv-scaling",
                           "b2-decode-dense", "b2-decode-moe"}
