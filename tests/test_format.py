"""Serpens format: roundtrip and invariant tests.

Hypothesis property tests live in ``test_format_properties.py`` (skipped
when ``hypothesis`` is not installed).
"""
import numpy as np
import pytest

from repro.core import format as F


def rand_coo(m, k, nnz, seed=0, dupes=False):
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, m, nnz)
    cols = rng.integers(0, k, nnz)
    vals = rng.normal(size=nnz).astype(np.float32)
    if not dupes:
        key = rows.astype(np.int64) * k + cols
        _, idx = np.unique(key, return_index=True)
        rows, cols, vals = rows[idx], cols[idx], vals[idx]
    return rows, cols, vals


CFG = F.SerpensConfig(segment_width=64, lanes=8, sublanes=4, raw_window=4)


def dense_of(rows, cols, vals, shape):
    out = np.zeros(shape, np.float32)
    np.add.at(out, (rows, cols), vals)
    return out


class TestRoundtrip:
    @pytest.mark.parametrize("m,k,nnz", [(50, 70, 300), (8, 8, 8),
                                         (200, 30, 900), (1, 1, 1)])
    def test_decode_recovers_coo(self, m, k, nnz):
        rows, cols, vals = rand_coo(m, k, nnz, seed=m + k)
        sm = F.encode(rows, cols, vals, (m, k), CFG)
        r2, c2, v2 = F.decode_to_coo(sm)
        assert dense_of(r2, c2, v2, (m, k)) == pytest.approx(
            dense_of(rows, cols, vals, (m, k)))

    def test_duplicates_preserved(self):
        rows = np.array([3, 3, 3, 3]); cols = np.array([5, 5, 5, 5])
        vals = np.array([1., 2., 3., 4.], np.float32)
        sm = F.encode(rows, cols, vals, (10, 10), CFG)
        r2, c2, v2 = F.decode_to_coo(sm)
        assert len(r2) == 4 and v2.sum() == 10.0
        F.check_invariants(sm)  # dupes must still be RAW-window separated

    def test_empty_matrix(self):
        sm = F.encode(np.array([], np.int64), np.array([], np.int64),
                      np.array([], np.float32), (16, 16), CFG)
        r2, c2, v2 = F.decode_to_coo(sm)
        assert len(r2) == 0
        F.check_invariants(sm)

    def test_row_capacity_guard(self):
        cfg = F.SerpensConfig(segment_width=64, lanes=2, sublanes=4)
        big_m = 2 * ((1 << 16) - 1) + 1
        with pytest.raises(ValueError, match="row capacity"):
            F.encode(np.array([big_m - 1]), np.array([0]),
                     np.array([1.0], np.float32), (big_m, 4), cfg)


class TestInvariants:
    def test_lane_ownership(self):
        rows, cols, vals = rand_coo(100, 100, 500, seed=2)
        sm = F.encode(rows, cols, vals, (100, 100), CFG)
        r2, _, _ = F.decode_to_coo(sm)
        idx = sm.idx.reshape(-1, CFG.lanes)
        live = idx != F.SENTINEL
        lanes = np.broadcast_to(np.arange(CFG.lanes), idx.shape)[live]
        assert np.all(r2 % CFG.lanes == lanes)

    def test_segment_monotone(self):
        rows, cols, vals = rand_coo(60, 500, 2000, seed=3)
        sm = F.encode(rows, cols, vals, (60, 500), CFG)
        assert np.all(np.diff(sm.seg_ids) >= 0)

    def test_hot_row_padding(self):
        """One row with many entries in one segment forces RAW padding."""
        n = 64
        rows = np.zeros(n, np.int64)
        cols = np.arange(n, dtype=np.int64)  # all in segment 0 (W=64)
        vals = np.ones(n, np.float32)
        sm = F.encode(rows, cols, vals, (8, 64), CFG)
        F.check_invariants(sm)
        # row 0 owns lane 0; 64 conflicting entries with window 4 need
        # ≥ 64*4 slots in that lane
        assert sm.idx.reshape(-1, CFG.lanes).shape[0] >= 64 * 4 - 3


class TestStats:
    def test_padding_ratio_and_stream_bytes(self):
        rows, cols, vals = rand_coo(128, 128, 512, seed=4)
        sm = F.encode(rows, cols, vals, (128, 128), CFG)
        assert sm.stream_bytes == sm.idx.size * 8
        assert 0.0 <= sm.padding_ratio < 1.0
        assert sm.idx.size >= sm.nnz


class TestSpill:
    """Beyond-paper hot-row spill + lane balancing (§Perf C3/C4)."""

    def test_spill_roundtrip_exact(self):
        rows = np.concatenate([np.zeros(200, np.int64),
                               np.arange(100, dtype=np.int64)])
        cols = np.concatenate([np.arange(200, dtype=np.int64),
                               np.arange(100, dtype=np.int64)])
        vals = np.random.default_rng(0).normal(size=300).astype(np.float32)
        cfg = F.SerpensConfig(segment_width=64, lanes=8, sublanes=4,
                              raw_window=2, spill_hot_rows=True,
                              lane_balance=1.25)
        sm = F.encode(rows, cols, vals, (128, 256), cfg)
        F.check_invariants(sm)
        assert sm.n_aux > 0   # the hot row must spill
        r2, c2, v2 = F.decode_to_coo(sm)
        np.testing.assert_allclose(dense_of(r2, c2, v2, (128, 256)),
                                   dense_of(rows, cols, vals, (128, 256)),
                                   rtol=1e-6, atol=1e-6)

    def test_spill_reduces_padding(self):
        rng = np.random.default_rng(1)
        # zipf-ish rows: heavy head
        rows = (rng.zipf(1.3, 4000) % 64).astype(np.int64)
        cols = rng.integers(0, 256, 4000)
        vals = rng.normal(size=4000).astype(np.float32)
        base = F.SerpensConfig(segment_width=64, lanes=8, sublanes=4,
                               raw_window=4)
        opt = F.SerpensConfig(segment_width=64, lanes=8, sublanes=4,
                              raw_window=2, spill_hot_rows=True,
                              lane_balance=1.25)
        p0 = F.encode(rows, cols, vals, (64, 256), base).padding_ratio
        p1 = F.encode(rows, cols, vals, (64, 256), opt).padding_ratio
        assert p1 < p0
