"""Serpens format: roundtrip and invariant tests.

Hypothesis property tests live in ``test_format_properties.py`` (skipped
when ``hypothesis`` is not installed).
"""
import numpy as np
import pytest

from repro.core import format as F


def rand_coo(m, k, nnz, seed=0, dupes=False):
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, m, nnz)
    cols = rng.integers(0, k, nnz)
    vals = rng.normal(size=nnz).astype(np.float32)
    if not dupes:
        key = rows.astype(np.int64) * k + cols
        _, idx = np.unique(key, return_index=True)
        rows, cols, vals = rows[idx], cols[idx], vals[idx]
    return rows, cols, vals


CFG = F.SerpensConfig(segment_width=64, lanes=8, sublanes=4, raw_window=4)


def dense_of(rows, cols, vals, shape):
    out = np.zeros(shape, np.float32)
    np.add.at(out, (rows, cols), vals)
    return out


class TestRoundtrip:
    @pytest.mark.parametrize("m,k,nnz", [(50, 70, 300), (8, 8, 8),
                                         (200, 30, 900), (1, 1, 1)])
    def test_decode_recovers_coo(self, m, k, nnz):
        rows, cols, vals = rand_coo(m, k, nnz, seed=m + k)
        sm = F.encode(rows, cols, vals, (m, k), CFG)
        r2, c2, v2 = F.decode_to_coo(sm)
        assert dense_of(r2, c2, v2, (m, k)) == pytest.approx(
            dense_of(rows, cols, vals, (m, k)))

    def test_duplicates_preserved(self):
        rows = np.array([3, 3, 3, 3]); cols = np.array([5, 5, 5, 5])
        vals = np.array([1., 2., 3., 4.], np.float32)
        sm = F.encode(rows, cols, vals, (10, 10), CFG)
        r2, c2, v2 = F.decode_to_coo(sm)
        assert len(r2) == 4 and v2.sum() == 10.0
        F.check_invariants(sm)  # dupes must still be RAW-window separated

    def test_empty_matrix(self):
        sm = F.encode(np.array([], np.int64), np.array([], np.int64),
                      np.array([], np.float32), (16, 16), CFG)
        r2, c2, v2 = F.decode_to_coo(sm)
        assert len(r2) == 0
        F.check_invariants(sm)

    def test_row_capacity_guard(self):
        # Narrow segments leave the column half of the packed word short of
        # 0xFFFF, so the full 2^16 lane-local rows are usable ...
        cfg = F.SerpensConfig(segment_width=64, lanes=2, sublanes=4)
        big_m = 2 * (1 << 16) + 1
        with pytest.raises(ValueError, match="row capacity"):
            F.encode(np.array([big_m - 1]), np.array([0]),
                     np.array([1.0], np.float32), (big_m, 4), cfg)
        # ... but at segment_width=65536 row 0xFFFF must stay reserved for
        # the null sentinel.
        cfg16 = F.SerpensConfig(segment_width=1 << 16, lanes=2, sublanes=4)
        big_m = 2 * ((1 << 16) - 1) + 1
        with pytest.raises(ValueError, match="sentinel"):
            F.encode(np.array([big_m - 1]), np.array([0]),
                     np.array([1.0], np.float32), (big_m, 4), cfg16)


class TestInvariants:
    def test_lane_ownership(self):
        rows, cols, vals = rand_coo(100, 100, 500, seed=2)
        sm = F.encode(rows, cols, vals, (100, 100), CFG)
        r2, _, _ = F.decode_to_coo(sm)
        idx = sm.idx.reshape(-1, CFG.lanes)
        live = idx != F.SENTINEL
        lanes = np.broadcast_to(np.arange(CFG.lanes), idx.shape)[live]
        assert np.all(r2 % CFG.lanes == lanes)

    def test_segment_monotone(self):
        rows, cols, vals = rand_coo(60, 500, 2000, seed=3)
        sm = F.encode(rows, cols, vals, (60, 500), CFG)
        assert np.all(np.diff(sm.seg_ids) >= 0)

    def test_hot_row_padding(self):
        """One row with many entries in one segment forces RAW padding."""
        n = 64
        rows = np.zeros(n, np.int64)
        cols = np.arange(n, dtype=np.int64)  # all in segment 0 (W=64)
        vals = np.ones(n, np.float32)
        sm = F.encode(rows, cols, vals, (8, 64), CFG)
        F.check_invariants(sm)
        # row 0 owns lane 0; 64 conflicting entries with window 4 need
        # ≥ 64*4 slots in that lane
        assert sm.idx.reshape(-1, CFG.lanes).shape[0] >= 64 * 4 - 3


class TestStats:
    def test_padding_ratio_and_stream_bytes(self):
        rows, cols, vals = rand_coo(128, 128, 512, seed=4)
        sm = F.encode(rows, cols, vals, (128, 128), CFG)
        assert sm.stream_bytes == sm.idx.size * 8
        assert 0.0 <= sm.padding_ratio < 1.0
        assert sm.idx.size >= sm.nnz


class TestConfigValidation:
    @pytest.mark.parametrize("kw", [dict(lanes=0), dict(lanes=-3),
                                    dict(sublanes=0), dict(raw_window=0),
                                    dict(tiles_per_chunk=0),
                                    dict(lane_balance=-0.5),
                                    dict(segment_width=0),
                                    dict(segment_width=1 << 17)])
    def test_bad_geometry_raises(self, kw):
        with pytest.raises(ValueError):
            F.SerpensConfig(**kw)

    def test_aux_fields_default_to_empty_arrays(self):
        sm = F.SerpensMatrix(
            shape=(8, 8), nnz=0, config=CFG,
            idx=np.full((1, 4, 8), F.SENTINEL, np.int32),
            val=np.zeros((1, 4, 8), np.float32),
            seg_ids=np.zeros((1,), np.int32), num_segments=1)
        assert sm.aux_rows is not None and sm.aux_rows.size == 0
        assert sm.aux_cols.dtype == np.int32
        assert sm.aux_vals.dtype == np.float32
        assert sm.n_aux == 0


def triples_sorted(r, c, v):
    order = np.lexsort((v, c, r))
    return (np.asarray(r)[order], np.asarray(c)[order],
            np.asarray(v)[order])


def assert_encoders_equivalent(rows, cols, vals, shape, cfg):
    """encode == encode_reference: round-trip multiset, aux selection,
    invariants, padding.  Shared with the hypothesis property suite."""
    sv = F.encode(rows, cols, vals, shape, cfg)
    sr = F.encode_reference(rows, cols, vals, shape, cfg)
    F.check_invariants(sv)
    F.check_invariants(sr)
    for a, b in zip(triples_sorted(*F.decode_to_coo(sv)),
                    triples_sorted(*F.decode_to_coo(sr))):
        np.testing.assert_array_equal(a, b)
    assert sv.n_aux == sr.n_aux
    for a, b in zip(
            triples_sorted(sv.aux_rows, sv.aux_cols, sv.aux_vals),
            triples_sorted(sr.aux_rows, sr.aux_cols, sr.aux_vals)):
        np.testing.assert_array_equal(a, b)
    assert sv.padding_ratio <= sr.padding_ratio + 1e-12
    assert sv.num_segments == sr.num_segments
    return sv, sr


class TestVectorizedVsReference:
    """Always-on equivalence checks (the hypothesis-driven suite lives in
    test_format_properties.py); these are the acceptance's explicit cases."""

    @pytest.mark.parametrize("spill", [False, True])
    def test_matches_reference(self, spill):
        cfg = F.SerpensConfig(segment_width=64, lanes=8, sublanes=4,
                              raw_window=3, spill_hot_rows=spill,
                              lane_balance=1.2 if spill else 0.0)
        rows, cols, vals = rand_coo(90, 200, 800, seed=9, dupes=True)
        sv, sr = assert_encoders_equivalent(rows, cols, vals, (90, 200), cfg)
        assert sv.idx.shape == sr.idx.shape

    @pytest.mark.parametrize("cfg", [F.PAPER_CONFIG, F.OPTIMIZED_CONFIG],
                             ids=["paper", "optimized"])
    def test_paper_geometries_random(self, cfg):
        rows, cols, vals = rand_coo(600, 9000, 4000, seed=21, dupes=True)
        assert_encoders_equivalent(rows, cols, vals, (600, 9000), cfg)

    @pytest.mark.parametrize("cfg", [F.PAPER_CONFIG, F.OPTIMIZED_CONFIG],
                             ids=["paper", "optimized"])
    def test_paper_geometries_power_law(self, cfg):
        from repro.data import matrices as M
        rows, cols, vals = M.power_law_graph(1500, 15_000, seed=5)
        assert_encoders_equivalent(rows, cols, vals, (1500, 1500), cfg)

    def test_empty_lanes(self):
        """Rows all ≡ 0 (mod lanes): every other lane stays empty."""
        cfg = F.SerpensConfig(segment_width=32, lanes=8, sublanes=4,
                              raw_window=4)
        rows = np.arange(0, 128, 8, dtype=np.int64)
        cols = np.arange(16, dtype=np.int64)
        vals = np.linspace(1, 2, 16).astype(np.float32)
        assert_encoders_equivalent(rows, cols, vals, (128, 64), cfg)

    def test_single_row_hot_matrix(self):
        """One row owns every non-zero — the worst RAW-window case."""
        cfg = F.SerpensConfig(segment_width=64, lanes=8, sublanes=4,
                              raw_window=4)
        n = 120
        rows = np.zeros(n, np.int64)
        cols = np.arange(n, dtype=np.int64) % 64
        vals = np.arange(n, dtype=np.float32) + 1
        sv, _ = assert_encoders_equivalent(rows, cols, vals, (8, 64), cfg)
        # Optimal schedule: (n-1)*T + 1 slots in lane 0, chunk-aligned.
        assert sv.idx.reshape(-1, cfg.lanes).shape[0] == -(
            -((n - 1) * 4 + 1) // 16) * 16

    def test_single_hot_row_with_spill(self):
        cfg = F.SerpensConfig(segment_width=64, lanes=8, sublanes=4,
                              raw_window=2, spill_hot_rows=True,
                              lane_balance=1.25)
        rows = np.concatenate([np.zeros(200, np.int64),
                               np.arange(100, dtype=np.int64)])
        cols = np.concatenate([np.arange(200, dtype=np.int64),
                               np.arange(100, dtype=np.int64)])
        vals = np.random.default_rng(0).normal(size=300).astype(np.float32)
        sv, _ = assert_encoders_equivalent(rows, cols, vals, (128, 256), cfg)
        assert sv.n_aux > 0

    def test_duplicate_row_col_entries(self):
        cfg = F.SerpensConfig(segment_width=32, lanes=4, sublanes=4,
                              raw_window=4)
        rows = np.array([3, 3, 3, 3, 7, 7], np.int64)
        cols = np.array([5, 5, 5, 5, 1, 1], np.int64)
        vals = np.array([1., 2., 3., 4., 5., 6.], np.float32)
        sv, _ = assert_encoders_equivalent(rows, cols, vals, (10, 10), cfg)
        r2, _, v2 = F.decode_to_coo(sv)
        assert len(r2) == 6 and v2.sum() == 21.0

    def test_all_empty(self):
        cfg = F.SerpensConfig(segment_width=32, lanes=4, sublanes=4,
                              raw_window=4)
        z = np.zeros(0, np.int64)
        assert_encoders_equivalent(z, z, np.zeros(0, np.float32),
                                   (16, 16), cfg)

    def test_prepare_reuse(self):
        rows, cols, vals = rand_coo(60, 120, 400, seed=11)
        prep = F.prepare(rows, cols, vals, (60, 120), CFG)
        sm1 = F.encode_prepared(prep)
        sm2 = F.encode(rows, cols, vals, (60, 120), CFG)
        np.testing.assert_array_equal(sm1.idx, sm2.idx)
        np.testing.assert_array_equal(sm1.val, sm2.val)
        np.testing.assert_array_equal(sm1.seg_ids, sm2.seg_ids)


class TestCSRIngest:
    def test_csr_views_are_zero_copy_and_encode(self):
        from repro.data import matrices as M
        rows, cols, vals = rand_coo(40, 64, 300, seed=6)
        order = np.lexsort((cols, rows))
        rows, cols, vals = rows[order], cols[order], vals[order]
        indptr = np.searchsorted(rows, np.arange(41))
        indices = cols.copy()
        data = vals.copy()
        r2, c2, v2 = M.coo_from_csr(indptr, indices, data)
        assert np.shares_memory(c2, indices) and np.shares_memory(v2, data)
        np.testing.assert_array_equal(r2, rows)
        sm_a = F.encode(r2, c2, v2, (40, 64), CFG)
        sm_b = F.encode(rows, cols, vals, (40, 64), CFG)
        np.testing.assert_array_equal(sm_a.idx, sm_b.idx)

    def test_csc_roundtrip(self):
        from repro.data import matrices as M
        rows, cols, vals = rand_coo(30, 20, 150, seed=8)
        order = np.lexsort((rows, cols))
        rows, cols, vals = rows[order], cols[order], vals[order]
        indptr = np.searchsorted(cols, np.arange(21))
        r2, c2, v2 = M.coo_from_csc(indptr, rows.copy(), vals.copy())
        np.testing.assert_array_equal(c2, cols)
        np.testing.assert_array_equal(r2, rows)
        sm = F.encode(r2, c2, v2, (30, 20), CFG)
        got = dense_of(*F.decode_to_coo(sm), (30, 20))
        assert got == pytest.approx(dense_of(rows, cols, vals, (30, 20)))

    def test_bad_indptr_raises(self):
        from repro.data import matrices as M
        with pytest.raises(ValueError, match="non-decreasing"):
            M.coo_from_csr(np.array([0, 3, 1]), np.zeros(3, np.int64),
                           np.zeros(3, np.float32))


class TestSentinelBoundary:
    """The (lane-local row 0xFFFF, col 0xFFFF) packed word equals the int32
    padding sentinel -1.  A live element must either be representable (it
    is, whenever segment_width < 65536 — the column half then can't
    saturate) or rejected at encode time — never silently dropped."""

    CORNER_CFG = F.SerpensConfig(segment_width=64, lanes=1, sublanes=2,
                                 raw_window=2)

    def corner_matrix(self):
        """One element at lane-local row 0xFFFF, max segment-local col."""
        m = 1 << 16
        return (np.array([m - 1]), np.array([63]),
                np.array([2.5], np.float32), (m, 64))

    def test_corner_slot_roundtrips(self):
        rows, cols, vals, shape = self.corner_matrix()
        for enc in (F.encode, F.encode_reference):
            sm = enc(rows, cols, vals, shape, self.CORNER_CFG)
            assert (sm.idx != F.SENTINEL).sum() == 1   # not dropped
            r2, c2, v2 = F.decode_to_coo(sm)
            assert list(r2) == [shape[0] - 1] and list(c2) == [63]
            assert v2[0] == np.float32(2.5)
            F.check_invariants(sm)

    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    def test_corner_slot_reaches_kernels(self, backend):
        from repro.core.spmv import SerpensSpMV
        rows, cols, vals, shape = self.corner_matrix()
        op = SerpensSpMV(rows, cols, vals, shape, self.CORNER_CFG)
        x = np.zeros(shape[1], np.float32)
        x[63] = 2.0
        y = np.asarray(op.matvec(x, backend=backend))
        assert y[shape[0] - 1] == np.float32(5.0)
        assert np.count_nonzero(y) == 1

    def test_full_width_segment_reserves_row(self):
        """At segment_width=65536 the corner slot would alias the sentinel:
        it must be rejected with a clear error, not encoded."""
        cfg = F.SerpensConfig(segment_width=1 << 16, lanes=1, sublanes=2,
                              raw_window=2)
        m = 1 << 16
        with pytest.raises(ValueError, match="sentinel"):
            F.encode(np.array([m - 1]), np.array([(1 << 16) - 1]),
                     np.array([1.0], np.float32), (m, 1 << 16), cfg)
        # One row less is fine even at full segment width.
        sm = F.encode(np.array([m - 2]), np.array([(1 << 16) - 1]),
                      np.array([1.0], np.float32), (m - 1, 1 << 16), cfg)
        r2, c2, v2 = F.decode_to_coo(sm)
        assert list(r2) == [m - 2] and list(c2) == [(1 << 16) - 1]

    def test_row_capacity_helper(self):
        assert F.row_capacity(self.CORNER_CFG) == 1 << 16
        cfg16 = F.SerpensConfig(segment_width=1 << 16)
        assert F.row_capacity(cfg16) == (1 << 16) - 1


class TestSpill:
    """Beyond-paper hot-row spill + lane balancing (§Perf C3/C4)."""

    def test_spill_roundtrip_exact(self):
        rows = np.concatenate([np.zeros(200, np.int64),
                               np.arange(100, dtype=np.int64)])
        cols = np.concatenate([np.arange(200, dtype=np.int64),
                               np.arange(100, dtype=np.int64)])
        vals = np.random.default_rng(0).normal(size=300).astype(np.float32)
        cfg = F.SerpensConfig(segment_width=64, lanes=8, sublanes=4,
                              raw_window=2, spill_hot_rows=True,
                              lane_balance=1.25)
        sm = F.encode(rows, cols, vals, (128, 256), cfg)
        F.check_invariants(sm)
        assert sm.n_aux > 0   # the hot row must spill
        r2, c2, v2 = F.decode_to_coo(sm)
        np.testing.assert_allclose(dense_of(r2, c2, v2, (128, 256)),
                                   dense_of(rows, cols, vals, (128, 256)),
                                   rtol=1e-6, atol=1e-6)

    def test_spill_reduces_padding(self):
        rng = np.random.default_rng(1)
        # zipf-ish rows: heavy head
        rows = (rng.zipf(1.3, 4000) % 64).astype(np.int64)
        cols = rng.integers(0, 256, 4000)
        vals = rng.normal(size=4000).astype(np.float32)
        base = F.SerpensConfig(segment_width=64, lanes=8, sublanes=4,
                               raw_window=4)
        opt = F.SerpensConfig(segment_width=64, lanes=8, sublanes=4,
                              raw_window=2, spill_hot_rows=True,
                              lane_balance=1.25)
        p0 = F.encode(rows, cols, vals, (64, 256), base).padding_ratio
        p1 = F.encode(rows, cols, vals, (64, 256), opt).padding_ratio
        assert p1 < p0
