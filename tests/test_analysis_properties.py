"""Property tests: the stream verifier as an oracle over random encodes.

Mirrors ``test_format_properties.py``: hypothesis generates (COO, config,
spec) triples; every encoder output must verify clean against its source,
and any single live-slot corruption must be caught.  Skipped wholesale when
hypothesis isn't installed (it is in CI).
"""
import pytest

pytest.importorskip("hypothesis")

import dataclasses  # noqa: E402

import numpy as np  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.analysis import verify_matrix, verify_plan  # noqa: E402
from repro.core import format as F  # noqa: E402
from repro.core import partition as PT  # noqa: E402

CONFIGS = st.builds(
    F.SerpensConfig,
    segment_width=st.sampled_from([16, 64, 256]),
    lanes=st.sampled_from([2, 4, 8]),
    sublanes=st.sampled_from([2, 4]),
    raw_window=st.integers(1, 4),
    tiles_per_chunk=st.sampled_from([1, 2]),
    value_dtype=st.sampled_from(["float32", "bfloat16"]),
    spill_hot_rows=st.booleans(),
    lane_balance=st.sampled_from([0.0, 1.2]))

SPECS = st.builds(
    PT.PlanSpec,
    partition=st.sampled_from(["single", "row", "col"]),
    num_shards=st.integers(1, 3),
    lane_assign=st.sampled_from(["modulo", "balanced"]))

COOS = st.builds(
    lambda m, k, nnz, seed: (m, k, *_coo(m, k, nnz, seed)),
    m=st.integers(1, 60), k=st.integers(1, 80),
    nnz=st.integers(0, 250), seed=st.integers(0, 2**31))


def _coo(m, k, nnz, seed):
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, m, nnz)
    cols = rng.integers(0, k, nnz)
    vals = rng.normal(size=nnz).astype(np.float32)
    return rows, cols, vals


@settings(max_examples=60, deadline=None)
@given(coo=COOS, cfg=CONFIGS, spec=SPECS)
def test_every_plan_verifies_clean(coo, cfg, spec):
    m, k, rows, cols, vals = coo
    plan = PT.make_plan(rows, cols, vals, (m, k), cfg, spec)
    d = verify_plan(plan, rows, cols, vals, mode="full")
    assert d.ok, d.format()


@settings(max_examples=40, deadline=None)
@given(coo=COOS, cfg=CONFIGS, slot=st.integers(0, 2**31))
def test_single_slot_corruption_is_caught(coo, cfg, slot):
    """Flipping any one live slot's column bit breaks the source proof."""
    m, k, rows, cols, vals = coo
    sm = F.encode(rows, cols, vals, (m, k), cfg)
    live = np.argwhere(np.asarray(sm.idx) != F.SENTINEL)
    if live.size == 0:
        return
    t, s, lane = (int(x) for x in live[slot % len(live)])
    idx = np.array(sm.idx)
    # XOR the column low bit: stays inside the (even-width) segment, so
    # only the round-trip-vs-source rule can see it — the sharpest oracle.
    idx[t, s, lane] ^= np.int32(1)
    bad = dataclasses.replace(sm, idx=idx)
    d = verify_matrix(bad, source=(rows, cols, vals))
    assert not d.ok
