"""Feature extraction + PlanTuner: heuristics, online learning, plumbing.

Covers the ISSUE-8 acceptance surface: features match a naive numpy
recomputation (property-tested when hypothesis is present), the tuner's
heuristic choices land where the feature analysis says they should on
synthetic extremes (banded -> column split family, power-law -> balanced
lanes + spill), online observations flip a seeded-wrong prior within a
few updates, the prior JSON round-trips, and the registry/service
``spec="auto"`` path records decisions + observations end to end.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro.core import autotune as AT
from repro.core import features as FE
from repro.core import format as F
from repro.core.registry import MatrixRegistry
from repro.serve.spmv_service import SpMVService

CFG = F.SerpensConfig(segment_width=64, lanes=8, sublanes=4, raw_window=4)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def feats(rows, cols, shape, cfg=CFG):
    return FE.compute_features(np.asarray(rows), np.asarray(cols),
                               shape, cfg)


def naive_features(rows, cols, shape, cfg):
    """Straight-line recomputation of every MatrixFeatures field."""
    rows = np.asarray(rows, np.int64)
    cols = np.asarray(cols, np.int64)
    m, k = shape
    nnz = rows.size
    per_row = np.array([(rows == r).sum() for r in range(m)], np.float64)
    mean = nnz / m
    cv = float(per_row.std() / mean) if mean else 0.0
    # Gini via mean absolute difference.
    if nnz:
        diffs = np.abs(per_row[:, None] - per_row[None, :])
        gini = float(diffs.sum() / (2.0 * m * m * per_row.mean()))
    else:
        gini = 0.0
    bandwidth = (float(np.abs(rows / (m - 1) - cols / (k - 1)).mean())
                 if nnz and m > 1 and k > 1 else 0.0)
    nseg = max(1, -(-k // cfg.segment_width))
    seg = np.array([((cols // cfg.segment_width) == s).sum()
                    for s in range(nseg)], np.float64)
    if nnz and nseg > 1:
        p = seg[seg > 0] / nnz
        locality = 1.0 - float(-(p * np.log(p)).sum()) / np.log(nseg)
    else:
        locality = 1.0
    lane = np.array([((rows % cfg.lanes) == l).sum()
                     for l in range(cfg.lanes)], np.float64)
    lane_imb = float(lane.max() / lane.mean()) if lane.mean() else 1.0
    return dict(nnz=int(nnz), density=nnz / (m * k), nnz_row_mean=mean,
                nnz_row_cv=cv, nnz_row_max=int(per_row.max()) if m else 0,
                gini=gini, bandwidth=bandwidth, segment_locality=locality,
                lane_imbalance=lane_imb, num_segments=nseg)


class TestFeatures:
    def test_matches_naive(self):
        rng = np.random.default_rng(0)
        for seed in range(5):
            rng = np.random.default_rng(seed)
            m, k = int(rng.integers(2, 60)), int(rng.integers(2, 90))
            nnz = int(rng.integers(1, 300))
            rows = rng.integers(0, m, nnz)
            cols = rng.integers(0, k, nnz)
            got = feats(rows, cols, (m, k))
            want = naive_features(rows, cols, (m, k), CFG)
            for name, val in want.items():
                np.testing.assert_allclose(
                    getattr(got, name), val, rtol=1e-12, atol=1e-12,
                    err_msg=f"seed={seed} field={name}")

    def test_cached_on_prepared_and_uses_bucket_key(self):
        rng = np.random.default_rng(1)
        rows = rng.integers(0, 40, 200)
        cols = rng.integers(0, 120, 200)
        vals = rng.normal(size=200).astype(np.float32)
        prep = F.prepare(rows, cols, vals, (40, 120), CFG)
        f1 = FE.features_of(prep)
        assert prep.features is f1 and FE.features_of(prep) is f1
        # bucket_key fast path == coordinate recompute
        f2 = FE.compute_features(prep.rows, prep.cols, (40, 120), CFG)
        assert f1 == f2

    def test_empty_matrix(self):
        f = feats([], [], (8, 8))
        assert f.nnz == 0 and f.gini == 0.0 and f.nnz_row_cv == 0.0
        assert "d-empty" in f.bucket()

    def test_bucket_extremes(self):
        # Diagonal band -> bw-band + cv-lo.
        n = 64
        diag = feats(np.arange(n), np.arange(n), (n, n))
        assert "cv-lo|bw-band" in diag.bucket()
        # One dense row among empties -> cv-hi, scattered.
        rows = np.zeros(n, np.int64)
        cols = np.arange(n)
        hot = feats(rows, cols, (n, n))
        assert "cv-hi" in hot.bucket() and hot.gini > 0.9
        # Aspect prefixes and segment-count suffixes.
        assert feats([0], [0], (64, 8)).bucket().startswith("tall|")
        assert feats([0], [0], (8, 64)).bucket().startswith("wide|")
        assert feats([0], [0], (8, 64)).bucket().endswith("|s1")
        assert feats([0], [0], (8, 256)).bucket().endswith("|s-few")
        assert feats([0], [0], (8, 4096)).bucket().endswith("|s-many")

    def test_scale_invariant_bucket(self):
        """Same structure at 2 scales (same density decade, comparable
        column-segment count) lands in the same bucket."""
        def band(n):
            r = np.repeat(np.arange(n), 3)
            c = np.clip(r + np.tile([-1, 0, 1], n), 0, n - 1)
            return feats(r, c, (n, n))
        assert band(150).bucket() == band(256).bucket()


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:
    HAVE_HYP = False

if HAVE_HYP:
    @settings(max_examples=40, deadline=None)
    @given(st.integers(2, 50), st.integers(2, 80), st.integers(1, 250),
           st.integers(0, 10_000))
    def test_property_features_match_naive(m, k, nnz, seed):
        rng = np.random.default_rng(seed)
        rows = rng.integers(0, m, nnz)
        cols = rng.integers(0, k, nnz)
        got = feats(rows, cols, (m, k))
        want = naive_features(rows, cols, (m, k), CFG)
        for name, val in want.items():
            np.testing.assert_allclose(getattr(got, name), val,
                                       rtol=1e-12, atol=1e-12,
                                       err_msg=name)


def power_law_feats(n=256, seed=3):
    from repro.data import matrices as M
    r, c, _ = M.power_law_graph(n, n * 10, seed=seed)
    return feats(r, c, (n, n))


def banded_feats(n=256):
    r = np.repeat(np.arange(n), 3)
    c = np.clip(r + np.tile([-1, 0, 1], n), 0, n - 1)
    return feats(r, c, (n, n))


class TestTunerHeuristics:
    def test_power_law_leads_with_balanced_spill(self):
        t = AT.PlanTuner(backend="xla", epsilon=0.0)
        d = t.choose(power_law_feats())
        assert d.candidate.lane_assign == "balanced"
        assert d.candidate.spill is True
        assert not d.explored and d.predicted == 0.0

    def test_banded_leads_with_col_split(self):
        t = AT.PlanTuner(backend="xla", epsilon=0.0)
        d = t.choose(banded_feats())
        assert d.candidate.partition == "col"
        assert d.candidate.num_shards == 2

    def test_pallas_candidates_never_override_raw_window(self):
        for f in (power_law_feats(), banded_feats()):
            for c in AT.default_candidates(f, backend="pallas"):
                assert c.raw_window is None

    def test_candidates_deduped(self):
        for f in (power_law_feats(), banded_feats()):
            cands = AT.default_candidates(f, backend="xla")
            keys = [c.key for c in cands]
            assert len(keys) == len(set(keys))


class TestTunerLearning:
    def test_observations_flip_seeded_wrong_prior(self):
        """A prior that ranks the wrong arm best loses within a few
        online observations (EWMA alpha=0.5, no exploration noise)."""
        f = power_law_feats()
        bucket = f.bucket()
        t = AT.PlanTuner(backend="xla", epsilon=0.0)
        cands = t.candidates(f)
        wrong, right = cands[0], cands[1]
        # Seed the wrong arm as heavily-measured best.
        for _ in range(3):
            t.observe(bucket, wrong, slots_per_s=1e9, requests_per_s=100.0)
        assert t.choose(f).candidate.key == wrong.key
        for i in range(4):
            t.observe(bucket, wrong, slots_per_s=1e8, requests_per_s=10.0)
            t.observe(bucket, right, slots_per_s=2e9, requests_per_s=500.0)
        assert t.choose(f).candidate.key == right.key

    def test_ranking_is_padding_invariant(self):
        """Equal wall time, more padded slots must NOT rank higher: the
        exploit score is requests/s, slots/s only telemetry."""
        f = power_law_feats()
        bucket = f.bucket()
        t = AT.PlanTuner(backend="xla", epsilon=0.0)
        a, b = t.candidates(f)[:2]
        # b pads 2x (twice the slots/s at the same request rate).
        t.observe(bucket, a, slots_per_s=1e6, requests_per_s=50.0)
        t.observe(bucket, b, slots_per_s=2e6, requests_per_s=50.0 - 1e-9)
        assert t.choose(f).candidate.key == a.key

    def test_epsilon_probes_least_observed(self):
        f = power_law_feats()
        t = AT.PlanTuner(backend="xla", epsilon=0.999, seed=0)
        picks = {t.choose(f).explored for _ in range(20)}
        assert True in picks             # epsilon fires
        d = next(d for d in (t.choose(f) for _ in range(20)) if d.explored)
        assert d.candidate.key != d.ranked[0]
        with pytest.raises(ValueError):
            AT.PlanTuner(epsilon=1.0)
        # explore=False always takes the ranked head.
        assert not t.choose(f, explore=False).explored

    def test_decision_metrics_counted(self):
        from repro import obs
        reg = obs.MetricsRegistry()
        t = AT.PlanTuner(backend="xla", epsilon=0.0, metrics=reg)
        t.choose(power_law_feats())
        t.observe("b", AT.TunerCandidate(), slots_per_s=10.0,
                  predicted=20.0)
        snap = reg.snapshot()
        assert "tuner_decisions_total" in snap
        assert "tuner_predicted_over_observed_ratio" in snap


class TestTunerPersistence:
    def test_json_roundtrip_exact(self):
        f = power_law_feats()
        t = AT.PlanTuner(backend="xla", epsilon=0.0)
        for i, c in enumerate(t.candidates(f)):
            t.observe(f.bucket(), c, slots_per_s=float(100 + i),
                      requests_per_s=float(10 + i))
        blob = json.loads(json.dumps(t.to_json()))
        t2 = AT.PlanTuner.from_json(blob, backend="xla", epsilon=0.0)
        assert t2.to_json() == t.to_json()
        assert t2.choose(f, explore=False).candidate.key \
            == t.choose(f, explore=False).candidate.key

    def test_load_accepts_sweep_artifact_wrapper(self, tmp_path):
        t = AT.PlanTuner(backend="xla")
        t.observe("bk", AT.TunerCandidate(), slots_per_s=5.0,
                  requests_per_s=2.0)
        artifact = {"matrices": [], "prior": t.to_json()}
        p = tmp_path / "sweep.json"
        p.write_text(json.dumps(artifact))
        t2 = AT.PlanTuner.load(p, backend="xla")
        assert t2.to_json()["buckets"] == t.to_json()["buckets"]

    def test_save_load(self, tmp_path):
        t = AT.PlanTuner(backend="xla")
        t.observe("bk", AT.TunerCandidate(lane_assign="balanced",
                                          spill=True), slots_per_s=7.0)
        p = tmp_path / "prior.json"
        t.save(p)
        t2 = AT.PlanTuner.load(p, backend="xla")
        assert t2.to_json() == t.to_json()

    def test_candidate_dict_roundtrip(self):
        c = AT.TunerCandidate("col", 2, "balanced", "xla", spill=True,
                              lane_balance=1.25, raw_window=2)
        c2 = AT.TunerCandidate.from_dict(
            json.loads(json.dumps(c.to_dict())))
        assert c2 == c and c2.key == c.key

    @pytest.mark.skipif(
        not os.path.exists(os.path.join(REPO, "results",
                                        "autotune_sweep.json")),
        reason="committed sweep artifact missing")
    def test_shipped_prior_roundtrips(self):
        """The committed sweep artifact loads as a prior and survives a
        save/load cycle (the CI gate, runnable locally)."""
        path = os.path.join(REPO, "results", "autotune_sweep.json")
        t = AT.PlanTuner.load(path, backend="xla")
        blob = t.to_json()
        assert blob["buckets"], "shipped prior has no buckets"
        t2 = AT.PlanTuner.from_json(blob, backend="xla")
        assert t2.to_json() == blob


def small_coo(m=48, k=64, nnz=500, seed=0, skew=False):
    rng = np.random.default_rng(seed)
    if skew:
        from repro.data import matrices as M
        r, c, v = M.power_law_graph(m, nnz, seed=seed)
        return r, c, v, (m, m)
    return (rng.integers(0, m, nnz), rng.integers(0, k, nnz),
            rng.normal(size=nnz).astype(np.float32), (m, k))


class TestRegistryAuto:
    def test_put_auto_correct_and_stats(self):
        r, c, v, shape = small_coo(m=64, nnz=900, seed=2, skew=True)
        reg = MatrixRegistry(config=CFG, backend="xla")
        mid = reg.put(r, c, v, shape, spec="auto")
        dense = np.zeros(shape, np.float64)
        np.add.at(dense, (r, c), v)
        x = np.random.default_rng(3).normal(size=shape[1]) \
            .astype(np.float32)
        y = np.asarray(reg.get(mid).matvec(x))
        np.testing.assert_allclose(y, dense @ x, atol=1e-3, rtol=1e-3)
        st = reg.encode_stats()[mid]
        assert st["auto_tuned"] and st["tune"]["bucket"]
        assert st["spec"].count(":") == 2
        assert reg.tune_decision(mid) is not None

    def test_repeat_auto_put_is_hit(self):
        r, c, v, shape = small_coo(seed=4)
        reg = MatrixRegistry(config=CFG, backend="xla")
        mid1 = reg.put(r, c, v, shape, spec="auto")
        mid2 = reg.put(r, c, v, shape, spec="auto")
        assert mid1 == mid2 and reg.stats.hits == 1

    def test_manual_put_records_no_tune(self):
        r, c, v, shape = small_coo(seed=5)
        reg = MatrixRegistry(config=CFG, backend="xla")
        mid = reg.put(r, c, v, shape)
        assert reg.encode_stats()[mid]["auto_tuned"] is False
        assert not reg.record_observation(mid, slots_per_s=1.0)
        assert not reg.retune(mid)

    def test_observation_and_retune_swaps_plan(self):
        r, c, v, shape = small_coo(m=64, nnz=900, seed=6, skew=True)
        reg = MatrixRegistry(config=CFG, backend="xla")
        mid = reg.put(r, c, v, shape, spec="auto")
        d = reg.tune_decision(mid)
        chosen = d.candidate.key
        other = next(k for k in d.ranked if k != chosen)
        # Hammer the tuner: chosen arm is slow, another arm is fast.
        for cand in AT.default_candidates(
                FE.compute_features(r, c, shape, CFG), backend="xla"):
            rate = 1e3 if cand.key == chosen else \
                (1e7 if cand.key == other else None)
            if rate:
                for _ in range(4):
                    reg.tuner.observe(d.bucket, cand, slots_per_s=rate,
                                      requests_per_s=rate)
        assert reg.retune(mid) is True
        d2 = reg.tune_decision(mid)
        assert d2.candidate.key == other
        # Plan swap preserved correctness.
        dense = np.zeros(shape, np.float64)
        np.add.at(dense, (r, c), v)
        x = np.random.default_rng(7).normal(size=shape[1]) \
            .astype(np.float32)
        np.testing.assert_allclose(np.asarray(reg.get(mid).matvec(x)),
                                   dense @ x, atol=1e-3, rtol=1e-3)
        # Re-tuning again with a stable ranking is a no-op.
        assert reg.retune(mid) is False

    def test_record_observation_feeds_tuner(self):
        r, c, v, shape = small_coo(seed=8)
        reg = MatrixRegistry(config=CFG, backend="xla")
        mid = reg.put(r, c, v, shape, spec="auto")
        d = reg.tune_decision(mid)
        assert reg.record_observation(mid, slots_per_s=123.0,
                                      requests_per_s=4.0)
        snap = reg.tuner.snapshot()[d.bucket]
        arm = next(a for a in snap if a["key"] == d.candidate.key)
        assert arm["count"] >= 1 and arm["score"] > 0


class TestServiceAuto:
    def test_dispatch_records_observations(self):
        r, c, v, shape = small_coo(m=64, nnz=700, seed=9, skew=True)
        reg = MatrixRegistry(config=CFG, backend="xla")
        mid = reg.put(r, c, v, shape, spec="auto")
        svc = SpMVService(reg, max_bucket=8, retune_every=4)
        dense = np.zeros(shape, np.float64)
        np.add.at(dense, (r, c), v)
        rng = np.random.default_rng(10)
        for _ in range(3):
            xs = rng.normal(size=(2, shape[1])).astype(np.float32)
            tickets = [svc.submit(mid, x) for x in xs]
            res = svc.flush()
            for t, x in zip(tickets, xs):
                np.testing.assert_allclose(res[t].y, dense @ x,
                                           atol=1e-3, rtol=1e-3)
        snap = svc.snapshot()
        assert snap["tuner_observations"].get(mid, 0) == 3
        assert snap["tuner"], "tuner state missing from snapshot"
        d = reg.tune_decision(mid)
        arm = next(a for a in snap["tuner"][d.bucket]
                   if a["key"] == d.candidate.key)
        assert arm["count"] == 3

    def test_retune_every_zero_disables(self):
        r, c, v, shape = small_coo(seed=11)
        reg = MatrixRegistry(config=CFG, backend="xla")
        mid = reg.put(r, c, v, shape, spec="auto")
        svc = SpMVService(reg, max_bucket=4, retune_every=0)
        x = np.random.default_rng(12).normal(size=shape[1]) \
            .astype(np.float32)
        svc.submit(mid, x)
        svc.flush()                     # records, but never retunes
        assert svc.snapshot()["tuner_observations"][mid] == 1
        with pytest.raises(ValueError):
            SpMVService(reg, retune_every=-1)
