"""AdamW unit tests: convergence, clipping, schedule, moment dtypes."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.train import optimizer as O


def test_adamw_converges_quadratic():
    cfg = O.OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=200,
                            weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = O.init(cfg, params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw |w|²
        params, opt, _ = O.update(cfg, grads, opt, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clip_caps_update():
    cfg = O.OptimizerConfig(lr=1.0, warmup_steps=0, grad_clip=1.0,
                            weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    opt = O.init(cfg, params)
    _, _, m = O.update(cfg, {"w": jnp.full(4, 1e6)}, opt, params)
    assert float(m["grad_norm"]) > 1e5  # reported norm is pre-clip


def test_schedule_warmup_and_decay():
    cfg = O.OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lr0 = float(O.schedule(cfg, jnp.asarray(1)))
    lr_mid = float(O.schedule(cfg, jnp.asarray(10)))
    lr_end = float(O.schedule(cfg, jnp.asarray(100)))
    assert lr0 < lr_mid
    assert lr_mid == 1.0
    assert abs(lr_end - 0.1) < 1e-5


def test_bf16_moments_shapes_and_progress():
    cfg = O.OptimizerConfig(lr=0.05, warmup_steps=0,
                            moment_dtype="bfloat16", weight_decay=0.0)
    params = {"w": jnp.asarray([2.0])}
    opt = O.init(cfg, params)
    assert opt["m"]["w"].dtype == jnp.bfloat16
    for _ in range(50):
        params, opt, _ = O.update(cfg, {"w": 2 * params["w"]}, opt, params)
    assert abs(float(params["w"][0])) < 1.0


def test_weight_decay_pulls_to_zero():
    cfg = O.OptimizerConfig(lr=0.1, warmup_steps=0, weight_decay=0.5)
    params = {"w": jnp.asarray([1.0])}
    opt = O.init(cfg, params)
    p2, _, _ = O.update(cfg, {"w": jnp.asarray([0.0])}, opt, params)
    assert float(p2["w"][0]) < 1.0
