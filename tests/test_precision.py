"""Mixed-precision value streams (bf16) + fused solver epilogues.

The bf16 stream's entire precision loss happens once, at encode time:
``Â = A + E``, ``|E| <= eps·|A|`` elementwise with ``eps = 2^-8``
(accumulation stays fp32 on every backend).  That gives an *analytic*
SpMV error bound — ``|Âx − Ax| <= eps·(|A| @ |x|)`` — which this suite
asserts across matrix families, spill configs and plan geometries.  The
rest covers the encode pipeline's bit-identity per dtype (cold ==
incremental splice == parallel encode), the operator/service dtype
boundary (silent promotion fixed → explicit TypeError), fused-epilogue
solver parity and its one-stream-pass-per-iteration guarantee, byte
accounting at 6 B/slot, and the solver tolerance floor clamp.
"""
import numpy as np
import pytest

from repro.core import format as F
from repro.core import parallel_encode as penc
from repro.core import partition as P
from repro.core.registry import MatrixRegistry
from repro.core.spmv import SerpensSpMV, from_dense
from repro.data import matrices as M
from repro.kernels import ops
from repro.serve.spmv_service import SpMVService
from repro.solvers import (conjugate_gradient, effective_tol, pagerank,
                           power_iteration, tolerance_floor, value_eps)
from test_format import dense_of, rand_coo
from test_update import (assert_plans_identical, make_delta,
                         post_delta_triples)

CFG = F.SerpensConfig(segment_width=64, lanes=8, sublanes=4, raw_window=4)
SPILL_CFG = F.SerpensConfig(segment_width=32, lanes=4, sublanes=4,
                            raw_window=2, spill_hot_rows=True,
                            lane_balance=1.2)
BF16 = {"value_dtype": "bfloat16"}
EPS_BF16 = 2.0 ** -8


def cfg_at(cfg, dtype):
    import dataclasses
    return dataclasses.replace(cfg, value_dtype=dtype)


def matrix_family(family, seed=0):
    """(rows, cols, vals, shape) for one test matrix family."""
    if family == "power_law":
        n = 96
        r, c, v = M.power_law_graph(n, 700, seed=seed)
        return r, c, v, (n, n)
    if family == "banded":
        n = 80
        r, c, v = M.banded(n, 5, seed=seed)
        return r, c, v, (n, n)
    if family == "uniform":
        r, c, v = M.uniform_random(70, 90, 600, seed=seed)
        return r, c, v, (70, 90)
    raise ValueError(family)


def ops_at_both(rows, cols, vals, shape, cfg, spec=P.PlanSpec(),
                backend="auto"):
    """The same matrix as fp32 and bf16 operators over one geometry."""
    mk = {}
    for dt in ("float32", "bfloat16"):
        plan = P.make_plan(rows, cols, vals, shape, cfg_at(cfg, dt), spec)
        from repro.core.spmv import SerpensOperator
        mk[dt] = SerpensOperator(plan, backend=backend)
    return mk["float32"], mk["bfloat16"]


class TestErrorBound:
    """|y_bf16 − y_fp32| <= eps_bf16 · (|A| @ |x|), elementwise.

    Both operators accumulate fp32 in the identical stream order, so the
    measured difference is purely the encode-time value rounding — the
    analytic bound must hold exactly (tiny atol for the subtraction)."""

    @pytest.mark.parametrize("family", ["power_law", "banded", "uniform"])
    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    def test_bound_across_families(self, family, backend):
        rows, cols, vals, shape = matrix_family(family, seed=7)
        op32, op16 = ops_at_both(rows, cols, vals, shape, CFG,
                                 backend=backend)
        rng = np.random.default_rng(11)
        x = rng.normal(size=shape[1]).astype(np.float32)
        y32 = np.asarray(op32.matvec(x), np.float64)
        y16 = np.asarray(op16.matvec(x), np.float64)
        a_abs = np.abs(dense_of(rows, cols, vals, shape)).astype(np.float64)
        bound = EPS_BF16 * (a_abs @ np.abs(x).astype(np.float64))
        assert np.all(np.abs(y16 - y32) <= bound + 1e-5)
        # and the error is real: bf16 differs from fp32 on generic data
        assert np.any(y16 != y32)

    @pytest.mark.parametrize("spec_args", [("single", 1), ("row", 2),
                                           ("row", 3), ("col", 2)])
    def test_bound_across_plan_geometries(self, spec_args):
        rows, cols, vals, shape = matrix_family("power_law", seed=3)
        op32, op16 = ops_at_both(rows, cols, vals, shape, CFG,
                                 spec=P.PlanSpec(*spec_args))
        rng = np.random.default_rng(5)
        x = rng.normal(size=shape[1]).astype(np.float32)
        y32 = np.asarray(op32.matvec(x), np.float64)
        y16 = np.asarray(op16.matvec(x), np.float64)
        a_abs = np.abs(dense_of(rows, cols, vals, shape)).astype(np.float64)
        bound = EPS_BF16 * (a_abs @ np.abs(x).astype(np.float64))
        assert np.all(np.abs(y16 - y32) <= bound + 1e-5)

    def test_bound_with_hot_row_spill(self):
        """Spill plans keep the aux COO side-stream fp32; the bound still
        holds (it is conservative for the spilled entries)."""
        rows, cols, vals, shape = matrix_family("power_law", seed=13)
        op32, op16 = ops_at_both(rows, cols, vals, shape, SPILL_CFG)
        assert op16.plan.n_aux > 0, "family must exercise the spill path"
        rng = np.random.default_rng(17)
        x = rng.normal(size=shape[1]).astype(np.float32)
        y32 = np.asarray(op32.matvec(x), np.float64)
        y16 = np.asarray(op16.matvec(x), np.float64)
        a_abs = np.abs(dense_of(rows, cols, vals, shape)).astype(np.float64)
        bound = EPS_BF16 * (a_abs @ np.abs(x).astype(np.float64))
        assert np.all(np.abs(y16 - y32) <= bound + 1e-5)

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_backends_bitwise_agree_per_dtype(self, dtype):
        """xla and pallas share the fp32 accumulation order, so they agree
        bitwise at *both* stream precisions."""
        rows, cols, vals, shape = matrix_family("uniform", seed=19)
        plan = P.make_plan(rows, cols, vals, shape, cfg_at(CFG, dtype),
                           P.PlanSpec())
        from repro.core.spmv import SerpensOperator
        op = SerpensOperator(plan, backend="auto")
        x = np.random.default_rng(23).normal(size=shape[1]).astype(
            np.float32)
        np.testing.assert_array_equal(np.asarray(op.matvec(x, backend="xla")),
                                      np.asarray(op.matvec(x,
                                                           backend="pallas")))


class TestBitIdentityPerDtype:
    """Cold encode == incremental splice == parallel encode, per dtype.

    Rounding to the stream dtype happens exactly once (fp32 master values
    in PreparedCOO, rounded at stream materialization), so every encode
    path must produce byte-identical val arrays."""

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    @pytest.mark.parametrize("mode", ["add", "set", "delete"])
    def test_splice_matches_cold_encode(self, dtype, mode):
        cfg = cfg_at(CFG, dtype)
        rows, cols, vals = rand_coo(96, 120, 700, seed=29, dupes=True)
        rows = np.asarray(rows, np.int64); cols = np.asarray(cols, np.int64)
        prep = F.prepare(rows, cols, vals, (96, 120), cfg)
        plan = P.plan_from_prepared(prep, P.PlanSpec())
        dr, dc, dv = make_delta(rows, cols, 96, 120, 50, seed=31,
                                overlap=20)
        new_plan, _, _ = P.plan_apply_delta(plan, prep, dr, dc, dv,
                                            mode=mode)
        rr, cc, vv = post_delta_triples(rows, cols,
                                        np.asarray(vals, np.float32),
                                        dr, dc, dv, 120, mode)
        cold = P.make_plan(rr, cc, vv, (96, 120), cfg, P.PlanSpec())
        assert str(new_plan.val.dtype) == dtype
        assert_plans_identical(new_plan, cold)

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    @pytest.mark.parametrize("spec_args", [("single", 1), ("row", 2)])
    def test_parallel_encode_matches_serial(self, dtype, spec_args):
        cfg = cfg_at(CFG, dtype)
        rows, cols, vals = rand_coo(128, 200, 1500, seed=37, dupes=True)
        spec = P.PlanSpec(*spec_args)
        serial = P.make_plan(rows, cols, vals, (128, 200), cfg, spec)
        _, parallel = penc.prepare_and_plan(rows, cols, vals, (128, 200),
                                            cfg, spec, n_workers=2)
        assert str(parallel.val.dtype) == dtype
        assert_plans_identical(parallel, serial)

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
    def test_reference_encoder_same_rounding(self, dtype):
        """The greedy reference encoder rounds identically: decoded
        multisets match the vectorized encoder's bit-for-bit."""
        cfg = cfg_at(F.SerpensConfig(segment_width=32, lanes=4, sublanes=4,
                                     raw_window=4), dtype)
        rows, cols, vals = rand_coo(40, 60, 250, seed=41, dupes=True)
        sv = F.encode(rows, cols, vals, (40, 60), cfg)
        sr = F.encode_reference(rows, cols, vals, (40, 60), cfg)
        np.testing.assert_array_equal(
            dense_of(*F.decode_to_coo(sv), (40, 60)),
            dense_of(*F.decode_to_coo(sr), (40, 60)))

    def test_bf16_roundtrip_within_eps(self):
        """encode→decode recovers A within one bf16 rounding per entry."""
        rows, cols, vals, shape = matrix_family("banded", seed=43)
        sm = F.encode(rows, cols, vals, shape, cfg_at(CFG, "bfloat16"))
        F.check_invariants(sm)
        got = dense_of(*F.decode_to_coo(sm), shape)
        want = dense_of(rows, cols, vals, shape)
        assert np.all(np.abs(got - want) <= EPS_BF16 * np.abs(want) + 1e-7)


class TestDtypeBoundary:
    """The silent-promotion fix: floating inputs cast to fp32 at the
    operator boundary, non-floating inputs are a TypeError."""

    def setup_method(self):
        rows, cols, vals = rand_coo(32, 48, 200, seed=47)
        self.op = SerpensSpMV(rows, cols, vals, (32, 48), CFG)

    def test_matvec_rejects_int(self):
        with pytest.raises(TypeError, match="floating"):
            self.op.matvec(np.arange(48))

    def test_matmat_rejects_int(self):
        with pytest.raises(TypeError, match="floating"):
            self.op.matmat(np.ones((48, 3), np.int32))

    def test_float64_casts_not_promotes(self):
        y = self.op.matvec(np.ones(48, np.float64))
        assert y.dtype == np.float32

    def test_beta_y_rejects_int(self):
        with pytest.raises(TypeError, match="floating"):
            self.op(np.ones(48, np.float32), beta=1.0,
                    y=np.zeros(32, np.int64))

    def test_service_submit_rejects_int(self):
        rows, cols, vals = rand_coo(24, 30, 120, seed=53)
        reg = MatrixRegistry(config=CFG)
        mid = reg.put(rows, cols, vals, (24, 30))
        svc = SpMVService(reg)
        with pytest.raises(TypeError, match="floating"):
            svc.submit(mid, np.arange(30))
        with pytest.raises(TypeError, match="floating"):
            svc.submit(mid, np.ones(30, np.float32), beta=1.0,
                       y=np.zeros(24, np.int32))


def spd_system(n=48, seed=0, dtype="float32"):
    rng = np.random.default_rng(seed)
    a = np.zeros((n, n), np.float32)
    idx = rng.integers(0, n, (4 * n, 2))
    a[idx[:, 0], idx[:, 1]] = rng.normal(size=4 * n)
    a = (a + a.T) / 2
    a[np.arange(n), np.arange(n)] = np.abs(a).sum(1) + 1.0
    op = from_dense(a, cfg_at(CFG, dtype))
    b = rng.normal(size=n).astype(np.float32)
    return op, a, b


class TestFusedSolvers:
    """fused="auto" epilogue path: parity with the two-phase body, one
    stream dispatch per iteration, and clean fallback/rejection."""

    @pytest.mark.parametrize("backend", ["xla", "pallas"])
    def test_cg_fused_matches_unfused(self, backend):
        op, a, b = spd_system(n=40 + (backend == "pallas") * 8, seed=59)
        assert op.supports_fused_epilogue
        rf = conjugate_gradient(op, b, tol=1e-6, fused=True,
                                backend=backend)
        ru = conjugate_gradient(op, b, tol=1e-6, fused=False,
                                backend=backend)
        assert rf.fused and not ru.fused
        assert rf.converged and ru.converged
        np.testing.assert_allclose(np.asarray(rf.x), np.asarray(ru.x),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(rf.x),
                                   np.linalg.solve(a, b), atol=1e-3)

    def test_pagerank_fused_matches_unfused(self):
        n = 88
        rows, cols, vals = M.power_law_graph(n, 600, seed=61)
        vals_n = M.column_normalize(rows, cols, vals, n)
        op = SerpensSpMV(rows, cols, vals_n, (n, n), CFG)
        rf = pagerank(op, tol=1e-7, max_iters=300, fused=True)
        ru = pagerank(op, tol=1e-7, max_iters=300, fused=False)
        assert rf.fused and rf.converged and ru.converged
        np.testing.assert_allclose(np.asarray(rf.x), np.asarray(ru.x),
                                   atol=1e-6)
        assert abs(float(np.asarray(rf.x).sum()) - 1.0) < 1e-3

    def test_power_iteration_fused_matches_unfused(self):
        op, a, _ = spd_system(n=36, seed=67)
        rf = power_iteration(op, tol=1e-6, fused=True)
        ru = power_iteration(op, tol=1e-6, fused=False)
        assert rf.fused and rf.converged
        assert rf.eigenvalue == pytest.approx(ru.eigenvalue, rel=1e-4)
        lam_max = float(np.linalg.eigvalsh(a)[-1])
        assert rf.eigenvalue == pytest.approx(lam_max, rel=1e-3)

    def test_fused_pagerank_is_one_dispatch_per_iteration(self):
        """Acceptance: the fused body issues exactly ONE stream dispatch
        per traced iteration (matrix + vector work in the same pass)."""
        n = 92       # distinct size: no trace-cache hit from other tests
        rows, cols, vals = M.power_law_graph(n, 640, seed=71)
        vals_n = M.column_normalize(rows, cols, vals, n)
        op = SerpensSpMV(rows, cols, vals_n, (n, n), CFG)
        d0 = ops.trace_dispatch_count()
        pagerank(op, tol=1e-6, max_iters=50, fused=True)
        assert ops.trace_dispatch_count() - d0 == 1

    def test_fused_cg_is_init_plus_one_dispatch(self):
        """CG traces two stream passes total: the r0 matvec and the single
        fused pass inside the while_loop body."""
        op, _, b = spd_system(n=52, seed=73)
        d0 = ops.trace_dispatch_count()
        conjugate_gradient(op, b, tol=1e-6, fused=True)
        assert ops.trace_dispatch_count() - d0 == 2

    def test_fused_rejected_on_multi_shard(self):
        rows, cols, vals, shape = matrix_family("uniform", seed=79)
        plan = P.make_plan(rows, cols, vals, (90, 90), CFG,
                           P.PlanSpec("row", 2))
        from repro.core.spmv import SerpensOperator
        op = SerpensOperator(plan)
        assert not op.supports_fused_epilogue
        b = np.ones(90, np.float32)
        with pytest.raises(ValueError, match="fused"):
            conjugate_gradient(op, b, fused=True)
        # auto falls back silently
        res = pagerank(op, max_iters=3, fused="auto")
        assert not res.fused

    def test_acc_layout_roundtrip(self):
        op, _, _ = spd_system(n=50, seed=83)
        v = np.random.default_rng(89).normal(size=50).astype(np.float32)
        back = np.asarray(op.from_acc_layout(op.to_acc_layout(v)))
        np.testing.assert_array_equal(back, v)


class TestToleranceFloor:
    def test_floor_values(self):
        assert tolerance_floor("float32") == 0.0
        assert tolerance_floor("bfloat16") == 4 * 2.0 ** -8
        assert value_eps("bfloat16") == 2.0 ** -8

    def test_clamp_warns_below_floor(self):
        with pytest.warns(UserWarning, match="precision"):
            tol, clamped = effective_tol(1e-9, "bfloat16")
        assert clamped and tol == tolerance_floor("bfloat16")

    def test_no_clamp_for_fp32(self):
        tol, clamped = effective_tol(1e-12, "float32")
        assert not clamped and tol == 1e-12

    def test_cg_clamps_and_still_converges(self):
        op16, a, b = spd_system(n=44, seed=97, dtype="bfloat16")
        op32, _, _ = spd_system(n=44, seed=97)
        with pytest.warns(UserWarning, match="precision"):
            r16 = conjugate_gradient(op16, b, tol=1e-9)
        assert r16.tol_effective == tolerance_floor("bfloat16")
        assert r16.converged
        r32 = conjugate_gradient(op32, b, tol=1e-9)
        # bf16 solve lands within its precision floor of the fp32 answer
        diff = np.linalg.norm(np.asarray(r16.x) - np.asarray(r32.x))
        scale = np.linalg.norm(np.asarray(r32.x))
        assert diff <= r16.tol_effective * scale * 4


class TestByteAccounting:
    """6 B/slot at bf16 everywhere bytes are counted: SerpensMatrix,
    ChannelShardPlan, cost_report, registry budget."""

    def test_stream_bytes_per_slot(self):
        rows, cols, vals, shape = matrix_family("uniform", seed=101)
        for dtype, per_slot in (("float32", 8), ("bfloat16", 6)):
            sm = F.encode(rows, cols, vals, shape, cfg_at(CFG, dtype))
            assert sm.stream_bytes == sm.idx.size * per_slot \
                + 12 * sm.n_aux

    def test_bf16_is_three_quarters_on_spill_free(self):
        rows, cols, vals, shape = matrix_family("banded", seed=103)
        s32 = F.encode(rows, cols, vals, shape, cfg_at(CFG, "float32"))
        s16 = F.encode(rows, cols, vals, shape, cfg_at(CFG, "bfloat16"))
        assert s32.n_aux == 0
        assert s16.stream_bytes * 4 == s32.stream_bytes * 3

    def test_cost_report_carries_dtype(self):
        rows, cols, vals, shape = matrix_family("uniform", seed=107)
        op32, op16 = ops_at_both(rows, cols, vals, shape, CFG)
        r32, r16 = op32.cost_report(), op16.cost_report()
        assert r32["value_dtype"] == "float32" \
            and r32["bytes_per_slot"] == 8
        assert r16["value_dtype"] == "bfloat16" \
            and r16["bytes_per_slot"] == 6
        assert r16["stream_bytes"] < r32["stream_bytes"]
        assert r16["bytes_per_nnz"] < r32["bytes_per_nnz"]

    def test_registry_keys_and_budget_per_dtype(self):
        rows, cols, vals, shape = matrix_family("uniform", seed=109)
        reg = MatrixRegistry(config=CFG)
        k32 = reg.put(rows, cols, vals, shape)
        k16 = reg.put(rows, cols, vals, shape, value_dtype="bfloat16")
        assert k32 != k16                   # dtype is part of the content key
        assert reg.get(k16).value_dtype == "bfloat16"
        assert reg.get(k16).plan.stream_bytes \
            < reg.get(k32).plan.stream_bytes
        # repeat put at the same dtype is a hit, not a re-encode
        h0 = reg.stats.hits
        assert reg.put(rows, cols, vals, shape,
                       value_dtype="bfloat16") == k16
        assert reg.stats.hits == h0 + 1
