"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + finite values.  (Assignment requirement: one smoke test per
assigned architecture.)"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, reduced_config, valid_cells
from repro.models.model import build
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import make_train_step
from repro.train import optimizer as opt_lib


def make_batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "inputs": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
    }
    if cfg.vision_tokens:
        batch["patches"] = jnp.asarray(rng.normal(
            size=(b, cfg.vision_tokens, cfg.vision_embed_dim)), jnp.float32)
    if cfg.encoder_layers:
        batch["frames"] = jnp.asarray(rng.normal(
            size=(b, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_train_step(arch):
    cfg = reduced_config(arch)
    lm = build(cfg)
    params = lm.init(jax.random.key(0))
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt_state = opt_lib.init(ocfg, params)
    batch = make_batch(cfg)
    step = jax.jit(make_train_step(lm, ocfg))
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually changed and shapes preserved
    a0 = jax.tree.leaves(params)[0]
    a1 = jax.tree.leaves(new_params)[0]
    assert a0.shape == a1.shape
    changed = any(
        not np.allclose(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(params),
                        jax.tree.leaves(new_params)))
    assert changed


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_forward_shapes(arch):
    cfg = reduced_config(arch)
    lm = build(cfg)
    params = lm.init(jax.random.key(1))
    batch = make_batch(cfg, b=2, s=16)
    logits, cache = jax.jit(lambda p, b: lm.prefill(p, b, 24 +
                                                    cfg.vision_tokens))(
        params, batch)
    assert logits.shape == (2, cfg.vocab_padded)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    # padded-vocab logits are masked
    if cfg.vocab_padded != cfg.vocab_size:
        assert float(jnp.max(logits[:, cfg.vocab_size:])) < -1e29


def test_full_configs_match_assignment():
    """Spot-check the exact assigned figures."""
    c = get_config("llama4-scout-17b-a16e")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (48, 5120, 40, 8, 8192, 202048)
    assert c.moe.num_experts == 16 and c.moe.top_k == 1
    c = get_config("jamba-1.5-large-398b")
    assert c.num_layers == 72 and c.moe.top_k == 2
    mix = [m for m, _ in c.layout]
    assert mix.count("attn") == 1 and mix.count("mamba") == 7
    c = get_config("minicpm3-4b")
    assert c.mla is not None and c.num_layers == 62
    c = get_config("mamba2-1.3b")
    assert c.ssm.d_state == 128 and c.num_heads == 0
    c = get_config("chatglm3-6b")
    assert c.rope_fraction == 0.5 and c.qkv_bias
    c = get_config("paligemma-3b")
    assert c.num_kv_heads == 1 and c.vision_tokens == 256


def test_param_count_sanity():
    """approx_params within expected magnitude of the public sizes."""
    expect = {
        "llama4-scout-17b-a16e": (90e9, 120e9),
        "llama4-maverick-400b-a17b": (330e9, 440e9),
        "chatglm3-6b": (5e9, 8e9),
        "minicpm3-4b": (3e9, 5.5e9),
        "qwen1.5-0.5b": (0.4e9, 0.7e9),
        "codeqwen1.5-7b": (6e9, 9e9),
        "mamba2-1.3b": (1.0e9, 1.6e9),
        "jamba-1.5-large-398b": (350e9, 440e9),
        "whisper-base": (0.04e9, 0.12e9),
        "paligemma-3b": (2e9, 4e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).approx_params()
        assert lo <= n <= hi, (arch, n)


def test_valid_cells_skip_rules():
    cells = valid_cells()
    assert ("mamba2-1.3b", "long_500k") in cells
    assert ("jamba-1.5-large-398b", "long_500k") in cells
    for arch in ("chatglm3-6b", "llama4-scout-17b-a16e", "whisper-base",
                 "paligemma-3b"):
        assert (arch, "long_500k") not in cells
    # every arch has the three universal shapes
    for arch in ARCHS:
        for shape in ("train_4k", "prefill_32k", "decode_32k"):
            assert (arch, shape) in cells
