"""Trainer: convergence, checkpoint/restart exactness, async save."""
import os
import tempfile

import numpy as np
import jax
import pytest

from repro.configs import reduced_config
from repro.data.pipeline import SyntheticLM
from repro.models.model import build
from repro.train import checkpoint as ckpt_lib
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer, TrainConfig


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config("qwen1.5-0.5b")
    lm = build(cfg)
    data = SyntheticLM(cfg.vocab_size, 32, 8, seed=3)
    return cfg, lm, data


def test_loss_decreases(setup):
    cfg, lm, data = setup
    tc = TrainConfig(steps=25, log_every=5,
                     opt=OptimizerConfig(lr=1e-2, warmup_steps=5,
                                         total_steps=25))
    tr = Trainer(lm, lambda s: data.batch_at(s), tc)
    hist = tr.run()
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.95


def test_checkpoint_restart_exact(setup):
    """Crash at step 20, restart, continue to 30 → identical params to an
    uninterrupted 30-step run (deterministic pipeline + restored state)."""
    cfg, lm, data = setup
    opt = OptimizerConfig(lr=5e-3, warmup_steps=2, total_steps=30)
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        tc_a = TrainConfig(steps=30, ckpt_dir=d1, ckpt_every=10,
                           ckpt_async=False, opt=opt)
        a = Trainer(lm, lambda s: data.batch_at(s), tc_a)
        a.run()

        tc_b = TrainConfig(steps=20, ckpt_dir=d2, ckpt_every=10,
                           ckpt_async=False, opt=opt)
        b1 = Trainer(lm, lambda s: data.batch_at(s), tc_b)
        b1.run()                       # "crash" after 20
        tc_b2 = TrainConfig(steps=30, ckpt_dir=d2, ckpt_every=10,
                            ckpt_async=False, opt=opt)
        b2 = Trainer(lm, lambda s: data.batch_at(s), tc_b2)
        assert b2.step == 20           # restored
        b2.run()
        for xa, xb in zip(jax.tree.leaves(a.params),
                          jax.tree.leaves(b2.params)):
            np.testing.assert_allclose(np.asarray(xa, np.float32),
                                       np.asarray(xb, np.float32),
                                       rtol=1e-6, atol=1e-6)


def test_checkpoint_gc_and_atomicity(setup):
    cfg, lm, data = setup
    with tempfile.TemporaryDirectory() as d:
        tree = {"x": np.arange(5.0)}
        for s in range(6):
            ckpt_lib.save(d, s, tree, keep=3)
        files = sorted(os.listdir(d))
        assert len(files) == 3 and files[-1] == "step_00000005.npz"
        assert not any(f.startswith("tmp") for f in files)
        restored, step = ckpt_lib.restore(d, {"x": np.zeros(5)})
        assert step == 5
        np.testing.assert_array_equal(restored["x"], np.arange(5.0))


def test_async_save_completes(setup):
    cfg, lm, data = setup
    with tempfile.TemporaryDirectory() as d:
        t = ckpt_lib.save_async(d, 7, {"w": np.ones((64, 64))})
        t.join()
        assert ckpt_lib.latest_step(d) == 7


def test_data_pipeline_deterministic():
    d1 = SyntheticLM(100, 16, 4, seed=9)
    d2 = SyntheticLM(100, 16, 4, seed=9)
    b1, b2 = d1.batch_at(123), d2.batch_at(123)
    np.testing.assert_array_equal(np.asarray(b1["inputs"]),
                                  np.asarray(b2["inputs"]))
    b3 = d1.batch_at(124)
    assert not np.array_equal(np.asarray(b1["inputs"]),
                              np.asarray(b3["inputs"]))


def test_data_is_learnable_structure():
    """The synthetic Markov stream has < log(vocab) entropy."""
    d = SyntheticLM(100, 64, 8, seed=0, branch=2)
    b = d.batch_at(0)
    # successor of token t is one of 2 choices 95% of the time
    inp = np.asarray(b["inputs"]); lab = np.asarray(b["labels"])
    hits = 0
    total = 0
    for bi in range(inp.shape[0]):
        for t in range(inp.shape[1]):
            total += 1
            if lab[bi, t] in d.succ[inp[bi, t]]:
                hits += 1
    assert hits / total > 0.8
