"""Hypothesis property tests for the Serpens format (optional dependency).

Skipped wholesale when ``hypothesis`` is not installed; the deterministic
format tests in ``test_format.py`` always run.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import format as F  # noqa: E402
from test_format import rand_coo, dense_of  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 120), st.integers(1, 150), st.integers(0, 400),
       st.integers(0, 10_000))
def test_property_roundtrip_and_raw(m, k, nnz, seed):
    rows, cols, vals = rand_coo(m, k, max(nnz, 0) or 1, seed, dupes=True)
    cfg = F.SerpensConfig(segment_width=32, lanes=4, sublanes=4,
                          raw_window=4)
    sm = F.encode(rows, cols, vals, (m, k), cfg)
    F.check_invariants(sm)
    r2, c2, v2 = F.decode_to_coo(sm)
    np.testing.assert_allclose(dense_of(r2, c2, v2, (m, k)),
                               dense_of(rows, cols, vals, (m, k)),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 100), st.integers(1, 120), st.integers(1, 400),
       st.integers(0, 9999))
def test_property_spill_preserves_matrix(m, k, nnz, seed):
    rows, cols, vals = rand_coo(m, k, nnz, seed, dupes=True)
    cfg = F.SerpensConfig(segment_width=32, lanes=4, sublanes=4,
                          raw_window=2, spill_hot_rows=True,
                          lane_balance=1.2)
    sm = F.encode(rows, cols, vals, (m, k), cfg)
    F.check_invariants(sm)
    r2, c2, v2 = F.decode_to_coo(sm)
    np.testing.assert_allclose(dense_of(r2, c2, v2, (m, k)),
                               dense_of(rows, cols, vals, (m, k)),
                               rtol=1e-5, atol=1e-5)
