"""Hypothesis property tests for the Serpens format (optional dependency).

Skipped wholesale when ``hypothesis`` is not installed; the deterministic
format tests in ``test_format.py`` — including the explicit
encode-vs-encode_reference equivalence cases — always run.

The core contract here is encoder equivalence: :func:`repro.core.format.
encode` (vectorized closed-form scheduler) must match
:func:`~repro.core.format.encode_reference` (per-lane greedy heapq, the
executable spec) on every generated matrix — identical recovered COO
multiset, identical spill selection, invariants hold, padding no worse.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import format as F  # noqa: E402
from test_format import (  # noqa: E402
    assert_encoders_equivalent, dense_of, rand_coo)


CONFIGS = st.sampled_from([
    F.SerpensConfig(segment_width=32, lanes=4, sublanes=4, raw_window=4),
    F.SerpensConfig(segment_width=32, lanes=4, sublanes=4, raw_window=1),
    F.SerpensConfig(segment_width=64, lanes=8, sublanes=2, raw_window=6,
                    tiles_per_chunk=2),
    # Spill + lane-balance paths (the OPTIMIZED_CONFIG mechanisms):
    F.SerpensConfig(segment_width=32, lanes=4, sublanes=4, raw_window=2,
                    spill_hot_rows=True, lane_balance=1.2),
    F.SerpensConfig(segment_width=32, lanes=4, sublanes=2, raw_window=3,
                    spill_hot_rows=True),
    F.SerpensConfig(segment_width=16, lanes=2, sublanes=2, raw_window=5,
                    lane_balance=1.05),
    # Non-power-of-two geometry (exercises the generic div/mod paths):
    F.SerpensConfig(segment_width=48, lanes=6, sublanes=3, raw_window=4),
])


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 120), st.integers(1, 150), st.integers(1, 400),
       st.integers(0, 10_000), CONFIGS)
def test_property_vectorized_matches_reference(m, k, nnz, seed, cfg):
    rows, cols, vals = rand_coo(m, k, nnz, seed, dupes=True)
    assert_encoders_equivalent(rows, cols, vals, (m, k), cfg)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 120), st.integers(1, 150), st.integers(0, 400),
       st.integers(0, 10_000))
def test_property_roundtrip_and_raw(m, k, nnz, seed):
    rows, cols, vals = rand_coo(m, k, max(nnz, 0) or 1, seed, dupes=True)
    cfg = F.SerpensConfig(segment_width=32, lanes=4, sublanes=4,
                          raw_window=4)
    sm = F.encode(rows, cols, vals, (m, k), cfg)
    F.check_invariants(sm)
    r2, c2, v2 = F.decode_to_coo(sm)
    np.testing.assert_allclose(dense_of(r2, c2, v2, (m, k)),
                               dense_of(rows, cols, vals, (m, k)),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(st.integers(4, 100), st.integers(4, 150), st.integers(1, 300),
       st.integers(1, 60), st.integers(0, 10_000), CONFIGS,
       st.sampled_from(["add", "set", "delete"]),
       st.sampled_from([("single", 1), ("row", 2), ("row", 3), ("col", 2)]))
def test_property_incremental_update_equals_cold_encode(
        m, k, nnz, nd, seed, cfg, mode, spec_args):
    """plan_apply_delta must be bit-identical to a cold encode of the
    post-delta matrix for every mode, geometry and partition."""
    from repro.core import partition as P
    from test_update import (assert_plans_identical, make_delta,
                             post_delta_triples)

    rows, cols, vals = rand_coo(m, k, nnz, seed, dupes=True)
    rng = np.random.default_rng(seed + 1)
    spec = P.PlanSpec(*spec_args)
    prep = F.prepare(rows, cols, vals, (m, k), cfg)
    plan = P.plan_from_prepared(prep, spec)
    dr, dc, dv = make_delta(np.asarray(rows, np.int64),
                            np.asarray(cols, np.int64), m, k, nd,
                            seed=seed + 2,
                            overlap=int(rng.integers(0, min(nd, nnz) + 1)))
    new_plan, merge, _ = P.plan_apply_delta(plan, prep, dr, dc, dv,
                                            mode=mode)
    rr, cc, vv = post_delta_triples(np.asarray(rows, np.int64),
                                    np.asarray(cols, np.int64),
                                    np.asarray(vals, np.float32),
                                    dr, dc, dv, k, mode)
    assert_plans_identical(new_plan, P.make_plan(rr, cc, vv, (m, k), cfg,
                                                 spec))
    cold_prep = F.prepare(rr, cc, vv, (m, k), cfg)
    np.testing.assert_array_equal(merge.prepared.order, cold_prep.order)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 100), st.integers(1, 120), st.integers(1, 400),
       st.integers(0, 9999))
def test_property_spill_preserves_matrix(m, k, nnz, seed):
    rows, cols, vals = rand_coo(m, k, nnz, seed, dupes=True)
    cfg = F.SerpensConfig(segment_width=32, lanes=4, sublanes=4,
                          raw_window=2, spill_hot_rows=True,
                          lane_balance=1.2)
    sm = F.encode(rows, cols, vals, (m, k), cfg)
    F.check_invariants(sm)
    r2, c2, v2 = F.decode_to_coo(sm)
    np.testing.assert_allclose(dense_of(r2, c2, v2, (m, k)),
                               dense_of(rows, cols, vals, (m, k)),
                               rtol=1e-5, atol=1e-5)
