"""On-device iterative solvers over the Serpens operator.

Covers PageRank (probability simplex + convergence), generic power
iteration (dominant eigenpair), and CG (residual drop, matches dense
solve) on both the ``xla`` and interpreted ``pallas`` backends.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import format as F
from repro.core.spmv import SerpensSpMV, from_dense
from repro.data import matrices as M
from repro.solvers import conjugate_gradient, pagerank, power_iteration

CFG = F.SerpensConfig(segment_width=64, lanes=8, sublanes=4, raw_window=4)
BACKENDS = ["xla", "pallas"]


def stochastic_graph_op(n=120, nnz=900, seed=0, backend="auto"):
    rows, cols, vals = M.power_law_graph(n, nnz, seed=seed)
    vals_n = M.column_normalize(rows, cols, vals, n)
    return SerpensSpMV(rows, cols, vals_n, (n, n), CFG, backend=backend)


def spd_op(n=64, seed=0, backend="auto"):
    """Sparse symmetric diagonally-dominant (hence SPD) matrix."""
    rng = np.random.default_rng(seed)
    a = np.zeros((n, n), np.float32)
    idx = rng.integers(0, n, (4 * n, 2))
    a[idx[:, 0], idx[:, 1]] = rng.normal(size=4 * n)
    a = (a + a.T) / 2
    a[np.arange(n), np.arange(n)] = np.abs(a).sum(1) + 1.0
    return from_dense(a, CFG, backend=backend), a


class TestPageRank:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_converges_to_distribution(self, backend):
        op = stochastic_graph_op(seed=1, backend=backend)
        res = pagerank(op, damping=0.85, tol=1e-6, max_iters=200,
                       backend=backend)
        r = np.asarray(res.x)
        assert res.converged and res.residual <= 1e-6
        assert abs(r.sum() - 1.0) < 1e-3        # probability vector
        assert np.all(r >= 0)
        assert 0 < res.iterations < 200

    def test_matches_dense_power_method(self):
        op = stochastic_graph_op(n=80, nnz=600, seed=2)
        res = pagerank(op, damping=0.85, tol=1e-10, max_iters=300)
        dense = op.to_dense()
        r = np.full(80, 1.0 / 80)
        for _ in range(300):
            link = 0.85 * dense @ r
            r = link + (1.0 - link.sum()) / 80
        np.testing.assert_allclose(np.asarray(res.x), r, atol=1e-4)

    def test_respects_max_iters(self):
        op = stochastic_graph_op(seed=3)
        res = pagerank(op, tol=0.0, max_iters=5)
        assert res.iterations == 5 and not res.converged

    def test_rejects_rectangular(self):
        rng = np.random.default_rng(4)
        op = SerpensSpMV(rng.integers(0, 10, 30), rng.integers(0, 20, 30),
                         rng.normal(size=30).astype(np.float32), (10, 20),
                         CFG)
        with pytest.raises(ValueError, match="square"):
            pagerank(op)


class TestPowerIteration:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_dominant_eigenpair(self, backend):
        rng = np.random.default_rng(5)
        # SPD ⇒ dominant eigenvalue real/positive, power method converges
        b = rng.normal(size=(40, 40)).astype(np.float32)
        a = b @ b.T / 40 + np.eye(40, dtype=np.float32)
        op = from_dense(a, CFG, backend=backend)
        res = power_iteration(op, tol=1e-5, max_iters=500, backend=backend)
        w = np.linalg.eigvalsh(a)
        assert res.converged
        assert res.eigenvalue == pytest.approx(w[-1], rel=1e-3)
        av = a @ np.asarray(res.x)
        np.testing.assert_allclose(av, res.eigenvalue * np.asarray(res.x),
                                   atol=1e-3)


class TestCG:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_solves_spd_system(self, backend):
        op, a = spd_op(seed=6, backend=backend)
        rng = np.random.default_rng(7)
        b = rng.normal(size=a.shape[0]).astype(np.float32)
        res = conjugate_gradient(op, b, tol=1e-6, backend=backend)
        assert res.converged
        x_ref = np.linalg.solve(a.astype(np.float64), b.astype(np.float64))
        np.testing.assert_allclose(np.asarray(res.x), x_ref, atol=1e-4,
                                   rtol=1e-3)

    def test_residual_drops(self):
        op, a = spd_op(seed=8)
        b = np.random.default_rng(9).normal(size=a.shape[0]) \
            .astype(np.float32)
        r0 = float(np.linalg.norm(b))            # x0 = 0 ⇒ initial residual
        res = conjugate_gradient(op, b, tol=1e-6)
        assert res.residual < 1e-4 * r0
        true_res = float(np.linalg.norm(b - a @ np.asarray(res.x)))
        assert true_res < 1e-3 * max(r0, 1.0)

    def test_warm_start_and_max_iters(self):
        op, a = spd_op(seed=10)
        b = np.random.default_rng(11).normal(size=a.shape[0]) \
            .astype(np.float32)
        full = conjugate_gradient(op, b, tol=1e-6)
        warm = conjugate_gradient(op, b, x0=full.x, tol=1e-6)
        assert warm.iterations <= 1
        capped = conjugate_gradient(op, b, tol=0.0, max_iters=3)
        assert capped.iterations == 3 and not capped.converged

    def test_rejects_bad_shapes(self):
        op, a = spd_op(seed=12)
        with pytest.raises(ValueError, match="expected"):
            conjugate_gradient(op, np.zeros(a.shape[0] + 1, np.float32))
