"""Staged serving pipeline: admission policies (block / reject /
shed-oldest, per-owner fairness), pipelined dispatch (results without
flush), on_ready re-entry of deferred requests, solver runs through the
admission gate, and the interleaved multi-owner stress test."""
import threading
import time

import numpy as np
import pytest

from repro.core import format as F
from repro.core import registry as R
from repro.serve.pipeline import (AdmissionConfig, AdmissionRejected,
                                  RequestShed, SpMVPipeline)
from repro.serve.spmv_service import SpMVService

CFG = F.SerpensConfig(segment_width=64, lanes=8, sublanes=4, raw_window=4)


def coo(m, k, nnz, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, m, nnz), rng.integers(0, k, nnz),
            rng.normal(size=nnz).astype(np.float32))


def dense_of(rows, cols, vals, shape):
    out = np.zeros(shape, np.float32)
    np.add.at(out, (rows, cols), vals)
    return out


def make(n=64, nnz=500, seed=0, **kw):
    rows, cols, vals = coo(n, n, nnz, seed=seed)
    reg = R.MatrixRegistry(config=CFG, backend="xla")
    mid = reg.put(rows, cols, vals, (n, n))
    svc = SpMVPipeline(reg, backend="xla", **kw)
    return svc, reg, mid, n, dense_of(rows, cols, vals, (n, n))


@pytest.fixture
def gated(monkeypatch):
    """Gate every encode on an event (see tests/test_background.py)."""
    gate = threading.Event()
    orig = R.penc.prepare_and_plan

    def waiting(*args, **kwargs):
        assert gate.wait(30), "test forgot to release the encode gate"
        return orig(*args, **kwargs)

    monkeypatch.setattr(R.penc, "prepare_and_plan", waiting)
    yield gate.set
    gate.set()


class TestAdmissionConfig:
    def test_policy_validated(self):
        with pytest.raises(ValueError, match="policy"):
            AdmissionConfig(policy="drop-newest")
        with pytest.raises(ValueError, match="max_pending"):
            AdmissionConfig(max_pending=0)
        with pytest.raises(ValueError, match="per_owner_cap"):
            AdmissionConfig(per_owner_cap=0)
        with pytest.raises(ValueError, match="block_timeout"):
            AdmissionConfig(block_timeout=0.0)

    def test_string_shorthand(self):
        svc, *_ = make(admission="reject")
        assert svc.admission.policy == "reject"
        with pytest.raises(ValueError):
            make(admission="bogus")


class TestRejectPolicy:
    def test_reject_raises_at_capacity(self):
        svc, reg, mid, n, _ = make(
            admission=AdmissionConfig("reject", max_pending=2))
        x = np.ones(n, np.float32)
        svc.submit(mid, x)
        svc.submit(mid, x)
        with pytest.raises(AdmissionRejected, match="queue"):
            svc.submit(mid, x)
        assert svc.stats.admitted == 2
        assert svc.stats.rejected == 1
        svc.flush()              # drains: the gate opens again
        svc.submit(mid, x)
        assert svc.stats.admitted == 3

    def test_per_owner_cap_is_per_owner(self):
        svc, reg, mid, n, _ = make(admission=AdmissionConfig(
            "reject", max_pending=16, per_owner_cap=1))
        x = np.ones(n, np.float32)
        svc.submit(mid, x, owner="a")
        with pytest.raises(AdmissionRejected, match="owner"):
            svc.submit(mid, x, owner="a")
        svc.submit(mid, x, owner="b")     # other owners unaffected
        assert svc.stats.admitted == 2 and svc.stats.rejected == 1


class TestShedOldestPolicy:
    def test_sheds_exactly_the_oldest(self):
        svc, reg, mid, n, dense = make(
            admission=AdmissionConfig("shed-oldest", max_pending=3))
        x = np.ones(n, np.float32)
        tickets = [svc.submit(mid, x) for _ in range(10)]
        assert tickets == list(range(10))
        assert svc.pending == 3
        assert svc.stats.shed == 7
        # FIFO eviction: exactly the 7 oldest tickets were shed, and each
        # shed ticket surfaces as a RequestShed error to its caller.
        for t in tickets[:7]:
            with pytest.raises(RequestShed):
                svc.result(t, timeout=1.0)
        svc.flush()
        for t in tickets[7:]:
            res = svc.result(t, timeout=1.0)
            np.testing.assert_allclose(res.y, dense @ x, rtol=1e-4,
                                       atol=1e-4)

    def test_owner_scoped_shed(self):
        # Only the per-owner cap trips: the victim is that owner's oldest,
        # never another caller's request.
        svc, reg, mid, n, _ = make(admission=AdmissionConfig(
            "shed-oldest", max_pending=16, per_owner_cap=1))
        x = np.ones(n, np.float32)
        t_b = svc.submit(mid, x, owner="b")
        t_a1 = svc.submit(mid, x, owner="a")
        t_a2 = svc.submit(mid, x, owner="a")   # sheds a's oldest, not b's
        with pytest.raises(RequestShed):
            svc.result(t_a1, timeout=1.0)
        svc.flush()
        assert svc.result(t_b, timeout=1.0).owner == "b"
        assert svc.result(t_a2, timeout=1.0).owner == "a"
        assert svc.results_dropped_by_owner() == {}   # shed != dropped
        assert svc.stats.shed == 1


class TestBlockPolicy:
    def test_block_times_out(self):
        svc, reg, mid, n, _ = make(admission=AdmissionConfig(
            "block", max_pending=1, block_timeout=0.3))
        x = np.ones(n, np.float32)
        svc.submit(mid, x)
        t0 = time.perf_counter()
        with pytest.raises(AdmissionRejected, match="block_timeout"):
            svc.submit(mid, x)
        assert time.perf_counter() - t0 >= 0.25
        assert svc.snapshot()["admission"]["block_waits"] == 1

    def test_block_unblocks_when_drained(self):
        svc, reg, mid, n, dense = make(admission=AdmissionConfig(
            "block", max_pending=1, block_timeout=10.0))
        x = np.ones(n, np.float32)
        svc.submit(mid, x)

        flusher = threading.Timer(0.2, svc.flush)
        flusher.start()
        try:
            t0 = time.perf_counter()
            t2 = svc.submit(mid, x)     # blocks until the flush drains
            waited = time.perf_counter() - t0
        finally:
            flusher.join()
        assert waited >= 0.1            # it really did backpressure
        svc.flush()
        np.testing.assert_allclose(svc.result(t2, timeout=1.0).y,
                                   dense @ x, rtol=1e-4, atol=1e-4)


class TestPipelinedMode:
    def test_results_without_flush(self):
        svc, reg, mid, n, dense = make(max_bucket=4)
        rng = np.random.default_rng(3)
        xs = [rng.normal(size=n).astype(np.float32) for _ in range(12)]
        with svc:
            assert svc.pipelined
            tickets = [svc.submit(mid, x) for x in xs]
            for t, x in zip(tickets, xs):
                res = svc.result(t, timeout=30.0)
                np.testing.assert_allclose(res.y, dense @ x, rtol=1e-4,
                                           atol=1e-4)
        assert not svc.pipelined
        st = svc.stats
        assert st.vectors == 12 and st.batches >= 3    # max_bucket=4

    def test_flush_is_a_drain_barrier(self):
        svc, reg, mid, n, _ = make()
        x = np.ones(n, np.float32)
        with svc:
            tickets = [svc.submit(mid, x) for _ in range(5)]
            assert svc.flush() == {}        # pipelined: drain, no dict
            # After the barrier every ticket is already deposited.
            for t in tickets:
                svc.result(t, timeout=0.5)

    def test_snapshot_reports_pipeline_state(self):
        svc, reg, mid, n, _ = make(inflight_depth=3)
        snap = svc.snapshot()
        assert snap["pipelined"] is False
        with svc:
            snap = svc.snapshot()
            assert snap["pipelined"] is True
            assert snap["queue_depth"] == 0
            assert snap["admission"]["policy"] == "block"
        assert svc.snapshot()["pipelined"] is False

    def test_start_is_idempotent_and_restartable(self):
        svc, reg, mid, n, _ = make()
        x = np.ones(n, np.float32)
        svc.start()
        svc.start()
        t = svc.submit(mid, x)
        assert svc.result(t, timeout=30.0).y is not None
        svc.stop()
        svc.start()                       # a stopped pipeline restarts
        t = svc.submit(mid, x)
        assert svc.result(t, timeout=30.0).y is not None
        svc.stop()

    def test_deferred_request_reenters_without_flush(self, gated):
        """The on_ready listener re-parks the request into the pipeline:
        results arrive with no flush() call anywhere."""
        release = gated
        reg = R.MatrixRegistry(config=CFG, backend="xla")
        r, c, v = coo(48, 48, 300, seed=5)
        svc = SpMVPipeline(reg, backend="xla")
        with svc:
            mid = reg.put(r, c, v, (48, 48), blocking=False)
            x = np.ones(48, np.float32)
            tickets = [svc.submit(mid, x) for _ in range(3)]
            assert svc.stats.deferred == 3   # counted at the gate
            release()
            for t in tickets:
                res = svc.result(t, timeout=30.0)
                np.testing.assert_allclose(
                    res.y, dense_of(r, c, v, (48, 48)) @ x,
                    rtol=1e-4, atol=1e-4)

    def test_evicted_mid_encode_fails_ticket_in_pipeline(self, gated):
        release = gated
        reg = R.MatrixRegistry(config=CFG, backend="xla")
        r, c, v = coo(32, 32, 200, seed=6)
        svc = SpMVPipeline(reg, backend="xla")
        with svc:
            mid = reg.put(r, c, v, (32, 32), blocking=False)
            t = svc.submit(mid, np.ones(32, np.float32))
            reg.evict(mid)
            release()
            with pytest.raises(KeyError):
                svc.result(t, timeout=30.0)


class TestSolveThroughGate:
    def test_submit_solve_validation(self):
        svc, reg, mid, n, _ = make()
        with pytest.raises(ValueError, match="unknown solver"):
            svc.submit_solve(mid, "gauss")
        with pytest.raises(ValueError, match="requires b"):
            svc.submit_solve(mid, "cg")
        with pytest.raises(ValueError, match="takes no b"):
            svc.submit_solve(mid, "pagerank", b=np.ones(n, np.float32))

    def test_pagerank_solve_sync(self):
        from repro.data import matrices as M
        from repro.solvers import pagerank
        n = 120
        rows, cols, vals = M.power_law_graph(n, 900, seed=7)
        vals_n = M.column_normalize(rows, cols, vals, n)
        reg = R.MatrixRegistry(config=CFG, backend="xla")
        mid = reg.put(rows, cols, vals_n, (n, n))
        svc = SpMVPipeline(reg, backend="xla")
        res = svc.solve(mid, "pagerank", tol=1e-5, owner="ranker")
        assert res.solve is not None and res.solve.converged
        assert res.owner == "ranker"
        ref = pagerank(reg.get(mid), tol=1e-5)
        np.testing.assert_allclose(res.y, np.asarray(ref.x),
                                   rtol=1e-4, atol=1e-5)
        # A solve charges one A-stream pass per iteration.
        assert svc.stats.stream_bytes == \
            reg.get(mid).stream_bytes * res.solve.iterations
        assert svc.stats.batches == 1 and svc.stats.vectors == 1

    def test_cg_solve_pipelined(self):
        # SPD system: diagonally dominant symmetric matrix.
        n = 32
        rng = np.random.default_rng(11)
        a = rng.normal(size=(n, n)).astype(np.float32) * 0.05
        a = a + a.T + np.eye(n, dtype=np.float32) * n
        rr, cc = np.nonzero(a)
        reg = R.MatrixRegistry(config=CFG, backend="xla")
        mid = reg.put(rr, cc, a[rr, cc], (n, n))
        svc = SpMVPipeline(reg, backend="xla")
        b = rng.normal(size=n).astype(np.float32)
        with svc:
            t = svc.submit_solve(mid, "cg", b=b, tol=1e-6)
            res = svc.result(t, timeout=60.0)
        assert res.solve.converged
        np.testing.assert_allclose(a @ res.y, b, rtol=1e-3, atol=1e-3)

    def test_solver_failure_becomes_error_result(self):
        svc, reg, mid, n, _ = make()
        t = svc.submit_solve(mid, "cg", b=np.ones(n, np.float32),
                             no_such_kw=1)   # solver raises TypeError
        svc.flush()
        with pytest.raises(TypeError):
            svc.result(t, timeout=1.0)
        assert svc.stats.batches == 0        # failed solve never counted

    def test_solves_and_spmv_share_the_gate(self):
        svc, reg, mid, n, _ = make(
            admission=AdmissionConfig("reject", max_pending=2))
        svc.submit(mid, np.ones(n, np.float32))
        svc.submit_solve(mid, "pagerank")
        with pytest.raises(AdmissionRejected):
            svc.submit_solve(mid, "pagerank")
        results = svc.flush()
        assert len(results) == 2


POLICIES = ("block", "reject", "shed-oldest")


class TestInterleavedStress:
    """Satellite acceptance: ≥3 owners interleaving submit / update /
    flush / evict(+re-put) under every admission policy — no torn
    snapshots, no lost tickets, shed only under shed-oldest."""

    @pytest.mark.parametrize("policy", POLICIES)
    def test_no_lost_tickets_no_torn_snapshots(self, policy):
        n, nnz = 48, 400
        rows, cols, vals = coo(n, n, nnz, seed=13)
        reg = R.MatrixRegistry(config=CFG, backend="xla")
        mid = reg.put(rows, cols, vals, (n, n))
        svc = SpMVService(reg, backend="xla", max_bucket=8,
                          admission=AdmissionConfig(
                              policy, max_pending=8, per_owner_cap=4,
                              block_timeout=0.2))
        stop = threading.Event()
        errors = []
        tickets_by_owner = {f"owner-{i}": [] for i in range(3)}
        rejected = {"n": 0}
        reject_lock = threading.Lock()

        def submitter(owner):
            x = np.ones(n, np.float32)
            while not stop.is_set():
                try:
                    t = svc.submit(mid, x, owner=owner)
                    tickets_by_owner[owner].append(t)
                except AdmissionRejected:
                    with reject_lock:
                        rejected["n"] += 1
                except KeyError:
                    pass                    # evictor raced us; re-put soon
                except Exception as e:      # pragma: no cover
                    errors.append(e)
                    return
                if len(tickets_by_owner[owner]) % 4 == 0:
                    try:
                        svc.flush()
                    except KeyError:
                        pass                # deferred op evicted mid-flush
                    except Exception as e:  # pragma: no cover
                        errors.append(e)
                        return

        def updater():
            rng = np.random.default_rng(17)
            while not stop.is_set():
                r = rng.integers(0, n, 4)
                c = rng.integers(0, n, 4)
                try:
                    svc.update(mid, r, c, np.ones(4, np.float32))
                except KeyError:
                    pass                    # evicted under us
                except Exception as e:      # pragma: no cover
                    errors.append(e)
                    return
                time.sleep(0.002)

        def evictor():
            while not stop.is_set():
                time.sleep(0.01)
                try:
                    reg.evict(mid)
                    reg.put(rows, cols, vals, (n, n), matrix_id=mid)
                except Exception as e:      # pragma: no cover
                    errors.append(e)
                    return

        threads = [threading.Thread(target=submitter,
                                    args=(f"owner-{i}",), name=f"owner-{i}")
                   for i in range(3)]
        threads += [threading.Thread(target=updater),
                    threading.Thread(target=evictor)]
        for t in threads:
            t.start()
        try:
            for _ in range(40):             # concurrent snapshot reader
                ss = svc.stats_snapshot()
                assert ss.batches >= 0 and ss.vectors >= 0
                assert ss.vectors <= ss.batches * svc.max_bucket
                assert ss.admitted >= 0 and ss.shed >= 0
                snap = svc.snapshot()
                assert snap["queue_depth"] >= 0
                assert snap["admission"]["policy"] == policy
                time.sleep(0.005)
        finally:
            stop.set()
            for t in threads:
                t.join()
        assert not errors, errors

        # Drain everything left in the queue.
        deadline = time.perf_counter() + 30
        while svc.pending:
            assert time.perf_counter() < deadline, "queue failed to drain"
            try:
                svc.flush()
            except KeyError:
                time.sleep(0.01)            # mid-re-put; retry

        # Every issued ticket resolves: a result, a stored error
        # (RequestShed / evicted-mid-encode), never a timeout (= a torn
        # ticket lost inside the pipeline).
        shed_seen = 0
        for owner, tickets in tickets_by_owner.items():
            assert tickets == sorted(tickets)   # monotonic per owner
            for t in tickets:
                try:
                    res = svc.result(t, timeout=5.0)
                    assert res.owner == owner
                    assert res.y is not None
                except RequestShed:
                    shed_seen += 1
                except TimeoutError:        # pragma: no cover
                    pytest.fail(f"ticket {t} ({owner}) lost in pipeline")
                except (KeyError, RuntimeError):
                    pass                    # failed explicitly: accounted
        st = svc.stats
        if policy == "shed-oldest":
            assert shed_seen == st.shed
            assert st.rejected == rejected["n"]
        else:
            assert st.shed == shed_seen == 0
        if policy == "reject":
            assert st.rejected == rejected["n"]
        n_issued = sum(len(v) for v in tickets_by_owner.values())
        assert st.admitted == n_issued
